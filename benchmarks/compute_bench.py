"""Compute-bound ECM: blocked matmul + flash attention (the in-core limit).

The paper validates the model's bandwidth-bound side on streaming kernels;
this section exercises the *other* side of Eq. 1 — workloads whose
``T_OL`` (FMA ports on the CPUs, the MXU systolic rate on the TPU) hides
the whole transfer chain.  Per machine it reports the light-speed ECM of
the cache-blocked GEMM and the flash-attention tiles, the ECM-ranked
block-size sweeps (``rank(..., objective="matmul"|"attention")``,
showing where blocking moves a kernel from the bandwidth-bound into the
core-bound regime), and interpret-mode validation of the Pallas kernels at
the autotuner-chosen blockings.

This module is a *section* of the merged suite runner — registration and
artifact emission live in ``benchmarks/run.py``:

    PYTHONPATH=src python -m benchmarks.run --suite compute [--machine M]
    PYTHONPATH=src python -m benchmarks.run --json --suite compute
"""
from __future__ import annotations

import time

from .util import fmt, pred_str, table

MATMUL_DIMS = (4096, 4096, 4096)
ATTENTION_DIMS = (4096, 4096, 128)         # (sq, skv, head_dim)


def _ecm_detail(model) -> dict:
    return {
        "levels": list(model.levels),
        "input_notation": model.notation(),
        "predictions": [float(x) for x in model.predictions()],
        "t_ol": float(model.t_ol),
        "t_nol": float(model.t_nol),
        "core_bound": model.core_bound(),
    }


def matmul_payload(dims=MATMUL_DIMS, machine: str | None = None) -> dict:
    """Light-speed ECM + ECM-ranked (bm, bn) blockings of a blocked GEMM."""
    from repro.core import workload_ecm
    from repro.core.autotune import rank
    from repro.kernels.matmul.ops import matmul_workload

    machine = machine or "haswell-ep"
    m, n, k = dims
    ranked = rank(dims, machine, objective="matmul")
    best = ranked[0]
    w = matmul_workload(m, n, k, bm=best["block"][0], bn=best["block"][1],
                        bk=best["block"][2])
    return {
        "dims": list(dims),
        "ecm": _ecm_detail(workload_ecm(w, machine)),
        "blocking": {"ranked": ranked, "best": best},
    }


def attention_payload(dims=ATTENTION_DIMS, machine: str | None = None,
                      causal: bool = True) -> dict:
    """Light-speed ECM + ECM-ranked (bq, bkv) tilings of flash attention."""
    from repro.core import workload_ecm
    from repro.core.autotune import rank
    from repro.kernels.attention.ops import attention_workload

    machine = machine or "haswell-ep"
    sq, skv, d = dims
    ranked = rank(dims, machine, objective="attention", causal=causal)
    best = ranked[0]
    w = attention_workload(sq, skv, d, bq=best["block"][0],
                           bk=best["block"][1], causal=causal)
    return {
        "dims": list(dims),
        "causal": causal,
        "ecm": _ecm_detail(workload_ecm(w, machine)),
        "blocking": {"ranked": ranked, "best": best},
    }


def kernel_payload(mm_dim: int = 256, att_seq: int = 256,
                   att_d: int = 64, repeats: int = 2,
                   machine: str | None = None) -> dict:
    """Interpret-mode validation of both Pallas kernels at the blockings
    the autotuner picks *for the suite's machine* (numerics vs the jnp
    oracles)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.attention import ops as att_ops, ref as att_ref
    from repro.kernels.matmul import ops as mm_ops, ref as mm_ref

    machine = machine or "haswell-ep"
    out: dict = {}
    key = jax.random.key(0)
    kx, ky, kq, kk, kv = jax.random.split(key, 5)

    bm, bn, bk = mm_ops.tuned_blocks(mm_dim, mm_dim, mm_dim,
                                     machine=machine)
    x = jax.random.normal(kx, (mm_dim, mm_dim), jnp.float32)
    y = jax.random.normal(ky, (mm_dim, mm_dim), jnp.float32)
    fn = lambda: mm_ops.matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
    got = np.asarray(jax.block_until_ready(fn()))
    want = np.asarray(mm_ref.matmul(x, y))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    err = float(np.max(np.abs(got - want)))
    out["matmul"] = {
        "shape": [mm_dim, mm_dim, mm_dim], "block": [bm, bn, bk],
        "max_abs_err": err, "matches_ref": bool(err < 1e-3),
        "wall_s": best,
    }

    bq, bkv = att_ops.tuned_blocks(att_seq, att_seq, att_d,
                                   machine=machine)
    shape = (1, att_seq, 1, att_d)
    q = jax.random.normal(kq, shape, jnp.float32)
    k = jax.random.normal(kk, shape, jnp.float32)
    v = jax.random.normal(kv, shape, jnp.float32)
    fn = lambda: att_ops.flash_attention(q, k, v, causal=True, bq=bq,
                                         bk=bkv, interpret=True)
    got = np.asarray(jax.block_until_ready(fn()))
    # the oracle takes fused (B*H, S, d) tensors
    flat = lambda t: t.transpose(0, 2, 1, 3).reshape(att_seq, att_d)[None]
    want = np.asarray(att_ref.attention(flat(q), flat(k), flat(v),
                                        causal=True))
    want = want.reshape(1, 1, att_seq, att_d).transpose(0, 2, 1, 3)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    err = float(np.max(np.abs(got - want)))
    out["attention"] = {
        "shape": list(shape), "block": [bq, bkv],
        "max_abs_err": err, "matches_ref": bool(err < 1e-3),
        "wall_s": best,
    }
    return out


def run(machine: str | None = None) -> str:
    machine = machine or "haswell-ep"
    out = []

    mm = matmul_payload(machine=machine)
    e = mm["ecm"]
    out.append(f"== blocked matmul {tuple(mm['dims'])} on {machine}: "
               f"{e['input_notation']} ==")
    out.append(f"T_ECM {pred_str(e['predictions'])}  "
               f"(T_OL={fmt(e['t_ol'], 1)}, T_nOL={fmt(e['t_nol'], 1)}; "
               f"{'core-bound' if e['core_bound'] else 'transfer-bound'})")
    rows = [[f"{r['block'][0]}x{r['block'][1]}", fmt(r["mem_lines"], 1),
             fmt(r["t_ecm"], 1), "yes" if r["core_bound"] else "no",
             fmt(r["speedup_vs_min_block"], 2) + "x"]
            for r in mm["blocking"]["ranked"][:8]]
    out.append(table(["bm x bn", "mem lines/CL", "T_ECM cy/CL",
                      "core-bound", "vs min block"], rows))
    out.append(f"autotuner pick: {tuple(mm['blocking']['best']['block'])}")

    att = attention_payload(machine=machine)
    e = att["ecm"]
    out.append(f"\n== flash attention (sq, skv, d)={tuple(att['dims'])}, "
               f"causal={att['causal']}, on {machine}: "
               f"{e['input_notation']} ==")
    out.append(f"T_ECM {pred_str(e['predictions'])}  "
               f"(T_OL={fmt(e['t_ol'], 1)}; "
               f"{'core-bound' if e['core_bound'] else 'transfer-bound'})")
    rows = [[f"{r['block'][0]}x{r['block'][1]}",
             fmt(r["tile_bytes"] / 1024, 0) + " KiB",
             "yes" if r["fits"] else "NO", fmt(r["t_ecm"], 1)]
            for r in att["blocking"]["ranked"][:8]]
    out.append(table(["bq x bkv", "tile bytes", "fits", "T_ECM cy/CL"],
                     rows))
    out.append(f"autotuner pick: {tuple(att['blocking']['best']['block'])}")

    k = kernel_payload(machine=machine)
    out.append("\n== Pallas kernels at the autotuned blockings "
               "(interpret mode, vs jnp oracles) ==")
    rows = [[name, "x".join(str(s) for s in v["shape"]),
             "x".join(str(b) for b in v["block"]),
             f"{v['max_abs_err']:.2e}",
             "yes" if v["matches_ref"] else "NO",
             fmt(v["wall_s"] * 1e3, 1)]
            for name, v in k.items()]
    out.append(table(["kernel", "shape", "block", "max |err|",
                      "matches ref", "wall ms"], rows))
    return "\n".join(out)


def main() -> int:
    print(run())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
