"""``--suite compose``: whole-model composed step predictions for the
config zoo.

Every architecture in ``repro.configs`` is walked into its op list by
``repro.core.compose``, lowered through the unified workload engine, and
composed into prefill/decode step predictions under the payload
machine's Eq. 1 overlap rule.  The "measured" side replays the same
lowered ops through the calibrated cache simulator
(``repro.simcache.simulate_lowered``) and recombines them under the same
rule, so predicted-vs-measured is a deterministic model-vs-model
comparison the CI regression gate can pin exactly.

``BENCH_compose.json`` records, per config: predicted and measured step
cycles per phase, useful FLOPs, memory-edge traffic and the dominant op;
plus the cross-machine zoo (composed cycles for every config on every
registry machine) and the composition throughput of the engine itself
(volatile, excluded from the gate).
"""
from __future__ import annotations

import time

from repro.configs import ARCH_NAMES, get_arch
from repro.core import compose
from repro.core.machine import get_machine, machine_names
from repro.core.workload import lower_many
from repro.simcache import simulate_lowered

#: one step shape for the whole table — batch 1, prefill over SEQ_LEN
#: tokens, decode one token against a SEQ_LEN-deep KV cache (equal
#: context, so the decode <= prefill invariant the tests pin applies)
BATCH = 1
SEQ_LEN = 4096

#: repetitions for the composition-throughput measurement
THROUGHPUT_REPEATS = 3


def _model_ops(name: str) -> list:
    cfg = get_arch(name).cfg
    return (compose.model_ops(cfg, "prefill", batch=BATCH, seq_len=SEQ_LEN)
            + compose.model_ops(cfg, "decode", batch=BATCH, seq_len=SEQ_LEN,
                                context=SEQ_LEN))


def measured_cycles(sp: compose.StepPrediction, sim, phase: str) -> float:
    """Recombine the calibrated simulator's per-op cy/CL under the same
    overlap rule as the prediction (``sim`` aligns with ``sp.ops``)."""
    idx = [i for i, o in enumerate(sp.ops) if o.phase == phase]
    t_ol, t_rest, serial = [], [], []
    for i in idx:
        op = sp.ops[i]
        scale = op.count * op.units
        extra = (float(sim[i]) - op.cy_per_unit) * scale
        t_ol.append(op.t_ol_cy)
        t_rest.append(op.t_rest_cy + extra)    # calibrated slowdown is
        serial.append(float(sim[i]) * scale)   # all data-side
    return compose.compose_cycles(t_ol, t_rest, serial, sp.alpha)


def arch_entry(name: str, machine: str = "tpu-v5e") -> dict:
    """Predicted + measured composed step for one config on ``machine``."""
    ops = _model_ops(name)
    sp = compose.compose_ops(ops, machine, name=name)
    lowered = lower_many([o.workload for o in ops], get_machine(machine))
    sim = simulate_lowered(lowered)[:, -1]
    out: dict = {"n_ops": len(ops)}
    for ph in compose.PHASES:
        predicted = sp.cycles(ph)
        measured = measured_cycles(sp, sim, ph)
        out[ph] = {
            "predicted_cy": predicted,
            "measured_cy": measured,
            "model_error": predicted / measured - 1.0,
            "flops": sp.flops(ph),
            "hbm_bytes": sp.hbm_bytes(ph),
            "dominant_op": sp.dominant_op(ph),
        }
    return out


def zoo_payload(machines=None) -> dict:
    """Composed prefill/decode cycles: every config x every machine."""
    machines = machines or machine_names()
    out: dict = {}
    for m in machines:
        out[m] = {}
        for name in ARCH_NAMES:
            sp = compose.predict_step(name, m, batch=BATCH,
                                      seq_len=SEQ_LEN, context=SEQ_LEN)
            out[m][name] = {"prefill_cy": sp.cycles("prefill"),
                            "decode_cy": sp.cycles("decode")}
    return out


def throughput_payload(machine: str = "tpu-v5e") -> dict:
    """End-to-end composition throughput (config -> StepPrediction)."""
    t0 = time.perf_counter()
    n = 0
    for _ in range(THROUGHPUT_REPEATS):
        for name in ARCH_NAMES:
            compose.predict_step(name, machine, batch=BATCH,
                                 seq_len=SEQ_LEN, context=SEQ_LEN)
            n += 1
    dt = time.perf_counter() - t0
    return {"n_compositions": n, "compose_wall_s": dt,
            "compositions_per_s": n / dt}


def compose_payload(machine: str = "tpu-v5e") -> dict:
    """The ``BENCH_compose.json`` payload body (envelope added by the
    runner)."""
    return {
        "shape": {"batch": BATCH, "seq_len": SEQ_LEN, "context": SEQ_LEN},
        "models": {name: arch_entry(name, machine) for name in ARCH_NAMES},
        "zoo": zoo_payload(),
        "throughput": throughput_payload(machine),
    }


def run(machine: str | None = None) -> str:
    """Human-readable report section."""
    machine = machine or "tpu-v5e"
    m = get_machine(machine)
    lines = [f"whole-model composed step predictions on {machine} "
             f"(batch {BATCH}, seq {SEQ_LEN}, "
             f"alpha={compose.overlap_alpha(m):.2f})",
             "",
             f"{'config':<24} {'prefill ms':>11} {'decode ms':>10} "
             f"{'err%':>7} {'dominant op':<18} {'ops':>4}"]
    for name in ARCH_NAMES:
        e = arch_entry(name, machine)
        pre_ms = e["prefill"]["predicted_cy"] / m.clock_hz * 1e3
        dec_ms = e["decode"]["predicted_cy"] / m.clock_hz * 1e3
        err = e["decode"]["model_error"] * 100
        lines.append(f"{name:<24} {pre_ms:>11.3f} {dec_ms:>10.4f} "
                     f"{err:>7.1f} {e['decode']['dominant_op']:<18} "
                     f"{e['n_ops']:>4}")
    lines.append("")
    lines.append("err% = composed prediction vs calibrated cache-simulator "
                 "recombination (decode phase); every row decomposes per "
                 "op / layer / phase via compose.predict_step")
    return "\n".join(lines)
