"""Run every benchmark: one section per paper table/figure, the TPU
adaptation, and the cross-generation machine-zoo tables — one suite-driven
runner for all kernel families.

    PYTHONPATH=src python -m benchmarks.run [--only <name>] [--suite <s>]
    PYTHONPATH=src python -m benchmarks.run --json [PATH] --suite stream
    PYTHONPATH=src python -m benchmarks.run --json --suite stencil
    PYTHONPATH=src python -m benchmarks.run --only machine_zoo --machine skylake-sp

``--suite {stream,stencil,compute,scaling,tpu,serve,compose,engine,mesh,
calibrate}``
selects a kernel family, the chip-level suite, the serving-engine suite,
the whole-model composition suite, the request-path engine suite, the
multi-chip mesh-autotuner suite, or the calibration-loop suite
(default: all sections); ``--machine`` picks a
registry machine for the sections and artifacts that are
machine-parameterized (the zoo table, the stencil sweep, the compute
blocking sweeps, the scaling/energy grids, the model-eval throughput
grid).

``--json`` skips the report sections and emits the perf-trajectory
artifact for the selected suite instead, in one shared BENCH schema
(validated by ``tools/check_bench.py``): a common envelope
(``schema``/``suite``/``machine``) plus the suite payload —
``BENCH_pipeline.json`` (stream: pipelined wall-clock + model-eval
throughput), ``BENCH_stencil.json`` (stencil: LC sweep + blocking +
kernel equality), ``BENCH_compute.json`` (compute: matmul/attention ECM +
block rankings + interpret-mode kernel validation),
``BENCH_scaling.json`` (chip level: Eq. 2 saturation table, Figs. 5/6
energy/EDP grids + optimal operating points, TPU DP scaling),
``BENCH_tpu.json`` (TPU: pipeline timings + the tpu-v5e zoo
predictions), ``BENCH_serve.json`` (serving engine: one
deterministic virtual-clock run per fault class — throughput, latency
percentiles, predicted-vs-measured step ratios, recovery counts) and
``BENCH_compose.json`` (whole-model composition: predicted-vs-measured
step cycles per config, the config x machine zoo, composition
throughput) and ``BENCH_engine.json`` (request-path engine: lowered-table
shape + deterministic T_ECM checksum, cold-lowering vs warm table-backed
eval rates, full-zoo Eq. 2 sweep latency, incremental re-rank speedup)
and ``BENCH_mesh.json`` (mesh autotuner: golden-pinned joint
(mesh x profile x block) winners per config x chip count, DP
bit-identity through the generalized path, warm mesh-sweep throughput)
and ``BENCH_calibrate.json`` (calibration loop: per-field-class fit
residuals, machine-file round-trip bit-identity, cold-vs-warm disk-cache
speedup with zero warm re-fits).
Field names are
stable across schema bumps so trajectories remain comparable; the CI
regression gate diffs fresh artifacts against the committed baselines
with ``tools/check_bench.py --compare``.
"""
from __future__ import annotations

import argparse
import inspect
import json
import time

from . import (
    calibrate_bench,
    compose_bench,
    compute_bench,
    engine_bench,
    fig11_bandwidth,
    fig12_nt_stores,
    fig789_sweeps,
    machine_zoo,
    mesh_bench,
    scaling_bench,
    serve_bench,
    stencil_sweep,
    table1_ecm,
    tpu_roofline,
    tpu_stream_ecm,
)

SECTIONS = [
    ("table1_ecm", "Table I: ECM model vs paper predictions & measurements",
     table1_ecm),
    ("fig789_sweeps", "Figs. 7-9: working-set sweeps + AGU-optimized triad",
     fig789_sweeps),
    ("scaling_bench",
     "Chip scaling + energy: Fig. 10 (Eq. 2), Figs. 5/6, TPU DP analogue",
     scaling_bench),
    ("fig11_bandwidth", "Fig. 11: sustained bandwidth across uarchs",
     fig11_bandwidth),
    ("fig12_nt_stores", "Fig. 12: non-temporal stores (ECM vs roofline)",
     fig12_nt_stores),
    ("stencil_sweep",
     "Stencil LC-ECM: 2D Jacobi sweeps + blocking (arXiv:1410.5010)",
     stencil_sweep),
    ("compute_bench",
     "Compute-bound ECM: blocked matmul + flash attention (in-core limit)",
     compute_bench),
    ("machine_zoo",
     "Machine zoo: every workload x every machine (arXiv:1702.07554)",
     machine_zoo),
    ("engine_bench",
     "Engine: lowered table, warm Eq. 1/Eq. 2 path, incremental re-rank",
     engine_bench),
    ("compose_bench",
     "Whole-model composition: config zoo step predictions (Eq. 1 x model)",
     compose_bench),
    ("serve_bench",
     "Model-guided serving: continuous batching under fault injection",
     serve_bench),
    ("mesh_bench",
     "Mesh autotuner: Eq. 2 over ICI, joint (mesh x profile x block) ranks",
     mesh_bench),
    ("calibrate_bench",
     "Calibration loop: fit residuals, machine-file round-trip, disk cache",
     calibrate_bench),
    ("tpu_stream_ecm", "TPU adaptation: Pallas stream kernels + TPU-ECM",
     tpu_stream_ecm),
    ("tpu_roofline", "TPU §Roofline: per (arch x shape x mesh) ECM terms",
     tpu_roofline),
]

#: section names per kernel-family suite (the zoo rides with every suite)
SUITES = {
    "stream": ["table1_ecm", "fig789_sweeps", "fig11_bandwidth",
               "fig12_nt_stores", "machine_zoo"],
    "stencil": ["stencil_sweep", "machine_zoo"],
    "compute": ["compute_bench", "machine_zoo"],
    "scaling": ["scaling_bench", "machine_zoo"],
    "tpu": ["tpu_stream_ecm", "tpu_roofline", "scaling_bench",
            "machine_zoo"],
    "serve": ["serve_bench", "machine_zoo"],
    "compose": ["compose_bench", "machine_zoo"],
    "engine": ["engine_bench", "machine_zoo"],
    "mesh": ["mesh_bench", "machine_zoo"],
    "calibrate": ["calibrate_bench", "machine_zoo"],
}

#: default artifact path per suite (schema: tools/check_bench.py)
BENCH_PATHS = {
    "stream": "BENCH_pipeline.json",
    "stencil": "BENCH_stencil.json",
    "compute": "BENCH_compute.json",
    "scaling": "BENCH_scaling.json",
    "tpu": "BENCH_tpu.json",
    "serve": "BENCH_serve.json",
    "compose": "BENCH_compose.json",
    "engine": "BENCH_engine.json",
    "mesh": "BENCH_mesh.json",
    "calibrate": "BENCH_calibrate.json",
}

BENCH_SCHEMA_VERSION = 2


def model_eval_benchmark(n_sizes: int = 2000, n_cores: int = 64,
                         machine: str = "haswell-ep") -> dict:
    """Model-eval throughput: vectorized batch path vs per-point API calls.

    The batch path evaluates the full (9 kernels x n_sizes) working-set
    surface and the (9 kernels x n_cores) scaling surface in a handful of
    array ops; the scalar baseline calls the per-point API the way the
    pre-batch ``sweep()`` / ``simulate_scaling()`` did (subsampled and
    extrapolated, it is that slow).

    The ``batch_*`` fields keep their historical *cold* semantics (engine
    caches bypassed, so the trajectory stays comparable across the table
    introduction); the ``warm_*`` fields time the steady-state request
    path — warm lowered-table rows plus memoized level curves, over a
    fixed ``warm_iters`` rep count.
    """
    import numpy as np

    from repro.core import BENCHMARKS
    from repro.core import engine as core_engine
    from repro.simcache import (
        EVAL_COUNTERS,
        reset_counters,
        scaling_batch,
        simulate_level,
        simulate_working_set,
        sweep_batch,
    )

    names = tuple(BENCHMARKS)
    sizes = list(np.geomspace(16 * 1024, 256 * 1024 * 1024, n_sizes))

    reset_counters()
    with core_engine.cache_disabled():
        t0 = time.perf_counter()
        _, surface = sweep_batch(names, sizes, machine=machine)
        _, scaling = scaling_batch(names, n_cores, machine=machine)
        dt_batch = time.perf_counter() - t0
    batch_points = int(surface.size + scaling.size)
    batch_array_evals = EVAL_COUNTERS["batch_array_evals"]

    # warm path: lowered-table rows + level-curve memo populated, then a
    # fixed rep count so the point total is deterministic
    warm_iters = 5
    sweep_batch(names, sizes, machine=machine)
    scaling_batch(names, n_cores, machine=machine)
    t0 = time.perf_counter()
    warm_points = 0
    for _ in range(warm_iters):
        _, surface = sweep_batch(names, sizes, machine=machine)
        _, scaling = scaling_batch(names, n_cores, machine=machine)
        warm_points += int(surface.size + scaling.size)
    dt_warm = time.perf_counter() - t0

    # scalar baseline: one API call per (kernel, size) point; 4 levels per
    # call internally (the old sweep() shape).  Subsample, then extrapolate.
    # Caches stay off so the baseline keeps measuring the per-point API.
    sub = sizes[:: max(n_sizes // 20, 1)]
    with core_engine.cache_disabled():
        t0 = time.perf_counter()
        for n in names:
            for s_ in sub:
                simulate_working_set(n, s_, machine=machine)
            for lv in range(4):
                simulate_level(n, lv, machine=machine)
        dt_sub = time.perf_counter() - t0
    scalar_points = len(names) * (len(sub) + 4)
    scalar_rate = scalar_points / dt_sub

    return {
        "batch_points": batch_points,
        "batch_wall_s": dt_batch,
        "batch_points_per_s": batch_points / dt_batch,
        "batch_array_evals": batch_array_evals,
        "python_calls_per_point_batch": batch_array_evals / batch_points,
        "scalar_points_per_s": scalar_rate,
        "python_calls_per_point_scalar": 1.0,
        "throughput_ratio": (batch_points / dt_batch) / scalar_rate,
        "per_point_call_reduction": batch_points / batch_array_evals,
        "cold_wall_s": dt_batch,
        "cold_points_per_s": batch_points / dt_batch,
        "warm_iters": warm_iters,
        "warm_points": warm_points,
        "warm_wall_s": dt_warm,
        "warm_points_per_s": warm_points / dt_warm,
        "warm_throughput_ratio": (warm_points / dt_warm)
        / (batch_points / dt_batch),
    }


def autotune_rank_benchmark(n_chips: int = 4096) -> dict:
    """Candidate-ranking throughput of the vectorized autotuner."""
    from repro.core.autotune import WorkloadSpec, candidates, estimate, rank

    w = WorkloadSpec(n_params=9_000_000_000, d_model=4096, n_layers=40,
                     global_batch=4096, seq_len=4096)
    cands = candidates(n_chips, w)
    t0 = time.perf_counter()
    ranked = rank(w, n_chips)
    dt_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for c in cands[: max(len(cands) // 4, 1)]:
        estimate(w, c)
    dt_scalar = (time.perf_counter() - t0) * 4
    return {
        "n_candidates": len(cands),
        "batch_rank_wall_s": dt_batch,
        "scalar_estimate_wall_s_extrapolated": dt_scalar,
        "best_config": ranked[0].summary() if ranked else None,
    }


def _envelope(suite: str, machine: str) -> dict:
    return {"schema": BENCH_SCHEMA_VERSION, "suite": suite,
            "machine": machine}


def stream_payload(machine: str = "haswell-ep") -> dict:
    return {
        **_envelope("stream", machine),
        "pipeline": tpu_stream_ecm.pipeline_timings(rows=256, repeats=3),
        "model_eval": model_eval_benchmark(machine=machine),
        "autotune": autotune_rank_benchmark(),
    }


def stencil_payload(machine: str = "haswell-ep") -> dict:
    return {
        **_envelope("stencil", machine),
        "sweep": stencil_sweep.sweep_payload(machine=machine),
        "blocking": stencil_sweep.blocking_payload(machine=machine),
        "kernels": stencil_sweep.kernel_payload(),
    }


def compute_payload(machine: str = "haswell-ep") -> dict:
    return {
        **_envelope("compute", machine),
        "matmul": compute_bench.matmul_payload(machine=machine),
        "attention": compute_bench.attention_payload(machine=machine),
        "kernels": compute_bench.kernel_payload(machine=machine),
    }


def scaling_payload(machine: str = "haswell-ep") -> dict:
    return {
        **_envelope("scaling", machine),
        "saturation": scaling_bench.saturation_payload(machine),
        "energy": scaling_bench.energy_payload(machine),
        "operating_points": scaling_bench.operating_points_payload(machine),
        "tpu_dp": scaling_bench.tpu_dp_payload(),
    }


def tpu_payload(machine: str = "tpu-v5e") -> dict:
    return {
        **_envelope("tpu", machine),
        "pipeline": tpu_stream_ecm.pipeline_timings(rows=128, repeats=1),
        "zoo": machine_zoo.zoo_payload([machine]),
    }


def serve_payload(machine: str = "tpu-v5e") -> dict:
    return {
        **_envelope("serve", machine),
        **serve_bench.serve_payload(machine=machine),
    }


def compose_payload(machine: str = "tpu-v5e") -> dict:
    return {
        **_envelope("compose", machine),
        **compose_bench.compose_payload(machine=machine),
    }


def engine_payload(machine: str = "haswell-ep") -> dict:
    return {
        **_envelope("engine", machine),
        **engine_bench.engine_payload(machine=machine),
        "zoo": machine_zoo.zoo_payload(),
    }


def mesh_payload(machine: str = "tpu-v5e") -> dict:
    return {
        **_envelope("mesh", machine),
        **mesh_bench.mesh_payload(machine=machine),
    }


def calibrate_payload(machine: str = "haswell-ep") -> dict:
    return {
        **_envelope("calibrate", machine),
        **calibrate_bench.calibrate_payload(machine=machine),
    }


def emit_json(path: str | None, suite: str = "stream",
              machine: str | None = None) -> str:
    """Write the suite's BENCH artifact; returns the path written."""
    builders = {"stream": stream_payload, "stencil": stencil_payload,
                "compute": compute_payload, "scaling": scaling_payload,
                "tpu": tpu_payload, "serve": serve_payload,
                "compose": compose_payload, "engine": engine_payload,
                "mesh": mesh_payload, "calibrate": calibrate_payload}
    if machine is None:
        machine = ("tpu-v5e" if suite in ("tpu", "serve", "compose", "mesh")
                   else "haswell-ep")
    payload = builders[suite](machine=machine)
    path = path or BENCH_PATHS[suite]
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    if suite == "stream":
        me = payload["model_eval"]
        print(f"[bench] wrote {path}: "
              f"{me['batch_points_per_s']:.0f} model points/s batch vs "
              f"{me['scalar_points_per_s']:.0f} scalar "
              f"({me['throughput_ratio']:.0f}x), "
              f"{me['per_point_call_reduction']:.0f}x fewer Python-level "
              f"calls per point")
    elif suite == "stencil":
        regimes = sorted({p["regime"] for p in payload["sweep"]})
        ok = all(s["bit_identical_to_ref"]
                 for s in payload["kernels"]["stages"].values())
        print(f"[bench] wrote {path}: {len(payload['sweep'])} sweep points "
              f"over regimes {regimes}, best block "
              f"{payload['blocking']['best']['block']} "
              f"({payload['blocking']['best']['speedup_vs_unblocked']:.2f}x),"
              f" kernels bit-identical: {ok}")
    elif suite == "scaling":
        sat = payload["saturation"]["workloads"]
        n_core = sum(1 for d in sat.values() if d["core_bound"])
        be = payload["energy"]["best_energy"]
        dp = payload["tpu_dp"]
        print(f"[bench] wrote {path}: Eq. 2 for {len(sat)} workloads "
              f"({n_core} core-bound), best energy "
              f"{be['energy_J']:.0f} J at {be['f_ghz']} GHz x "
              f"{be['n_cores']} cores, TPU DP saturation "
              f"~{dp['n_saturation']} chips")
    elif suite == "serve":
        cls = payload["classes"]
        lost = sum(c["lost"] for c in cls.values())
        req = sum(c["requeued"] for c in
                  (v["recovery"] for v in cls.values()))
        base = cls["none"]
        print(f"[bench] wrote {path}: {len(cls)} fault classes x "
              f"{payload['trace']['n_requests']} requests, "
              f"{base['tok_rate']:.0f} tok/s fault-free, "
              f"{req} fault requeues recovered, lost requests: {lost}")
    elif suite == "compose":
        models = payload["models"]
        dominant = {e["decode"]["dominant_op"] for e in models.values()}
        tp = payload["throughput"]
        print(f"[bench] wrote {path}: {len(models)} configs composed on "
              f"{machine} x {len(payload['zoo'])} zoo machines, decode "
              f"dominated by {sorted(dominant)}, "
              f"{tp['compositions_per_s']:.0f} compositions/s")
    elif suite == "engine":
        tab, warm = payload["table"], payload["warm_eval"]
        zoo, rr = payload["zoo_sweep"], payload["rerank"]
        print(f"[bench] wrote {path}: {tab['rows']} table rows "
              f"({tab['n_workloads']} workloads x {tab['n_machines']} "
              f"machines), warm eval {warm['points_per_s'] / 1e6:.1f} M "
              f"points/s, {zoo['sweeps_per_s']:.0f} zoo sweeps/s, "
              f"incremental re-rank {rr['speedup']:.1f}x "
              f"(identical: {rr['identical']})")
    elif suite == "mesh":
        ranks, dp = payload["rankings"], payload["dp_scaling"]
        sw = payload["sweep"]
        winners = {cell["winner"]["mesh"] + "/" + cell["winner"]["profile"]
                   for by_n in ranks.values() for cell in by_n.values()}
        print(f"[bench] wrote {path}: {len(ranks)} configs x "
              f"{len(sw['chip_counts'])} chip counts, {sw['plans']} plans "
              f"ranked ({sw['plans_per_s']:.0f} plans/s warm), "
              f"{len(winners)} distinct winners, DP bit-identical: "
              f"{dp['bit_identical']}")
    elif suite == "calibrate":
        fit, rt, c = payload["fit"], payload["roundtrip"], payload["cache"]
        print(f"[bench] wrote {path}: {fit['n_snapped']}/{fit['n_fields']} "
              f"fields snapped on {fit['base']} (max residual "
              f"{fit['residual_max']:.2e}), machine file bit-identical: "
              f"{rt['machine_equal_prior']}, warm cache {c['speedup']:.1f}x "
              f"with {c['warm_fits']} re-fits")
    elif suite == "compute":
        mm, att = payload["matmul"], payload["attention"]
        ok = all(v["matches_ref"] for v in payload["kernels"].values())
        print(f"[bench] wrote {path}: matmul {tuple(mm['dims'])} "
              f"best block {tuple(mm['blocking']['best']['block'])} "
              f"(core-bound: {mm['ecm']['core_bound']}), attention best "
              f"{tuple(att['blocking']['best']['block'])}, kernels match "
              f"ref: {ok}")
    else:
        n = len(payload["zoo"].get(machine, {}))
        print(f"[bench] wrote {path}: {n} workloads predicted on {machine}")
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[s[0] for s in SECTIONS])
    ap.add_argument("--suite", default=None,
                    choices=sorted(SUITES),
                    help="kernel-family suite; filters report sections and "
                         "selects the --json payload (default: all sections"
                         " / the stream artifact)")
    ap.add_argument("--machine", default=None,
                    help="machine for machine-parameterized sections and "
                         "artifacts: a registry name/alias (see "
                         "repro.core.MACHINES) or a calibrated "
                         "machine-file path (registered on load)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="emit the suite's perf-trajectory JSON instead of "
                         "the report sections")
    args = ap.parse_args()
    if args.machine is not None:
        # accept a machine-file path anywhere a registry name works: the
        # file is registered and the run proceeds under its name
        from repro.core.machine import resolve_machine
        args.machine = resolve_machine(args.machine).name
    if args.json is not None:
        emit_json(args.json or None, suite=args.suite or "stream",
                  machine=args.machine)
        return 0
    keep = set(SUITES[args.suite]) if args.suite else None
    # the tpu suite's report defaults its machine-parameterized sections
    # to the TPU entry (matching the json path) instead of the CPU pair
    machine = args.machine or ("tpu-v5e" if args.suite == "tpu" else None)
    for name, title, mod in SECTIONS:
        if args.only and name != args.only:
            continue
        if keep is not None and name not in keep:
            continue
        t0 = time.time()
        print(f"\n{'=' * 78}\n== {title}\n{'=' * 78}")
        # machine-parameterized sections accept the --machine flag
        if "machine" in inspect.signature(mod.run).parameters:
            print(mod.run(machine=machine))
        else:
            print(mod.run())
        print(f"[{name}: {time.time() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
