"""Run every benchmark: one section per paper table/figure, plus the TPU
adaptation (stream kernels + §Roofline table from the dry-run artifacts).

    PYTHONPATH=src python -m benchmarks.run [--only <name>]
"""
from __future__ import annotations

import argparse
import time

from . import (
    fig10_scaling,
    fig11_bandwidth,
    fig12_nt_stores,
    fig56_energy,
    fig789_sweeps,
    table1_ecm,
    tpu_energy,
    tpu_roofline,
    tpu_scaling,
    tpu_stream_ecm,
)

SECTIONS = [
    ("table1_ecm", "Table I: ECM model vs paper predictions & measurements",
     table1_ecm),
    ("fig789_sweeps", "Figs. 7-9: working-set sweeps + AGU-optimized triad",
     fig789_sweeps),
    ("fig10_scaling", "Fig. 10: multicore scaling, CoD vs non-CoD (Eq. 2)",
     fig10_scaling),
    ("fig56_energy", "Figs. 5/6: energy-to-solution and EDP grids",
     fig56_energy),
    ("fig11_bandwidth", "Fig. 11: sustained bandwidth across uarchs",
     fig11_bandwidth),
    ("fig12_nt_stores", "Fig. 12: non-temporal stores (ECM vs roofline)",
     fig12_nt_stores),
    ("tpu_stream_ecm", "TPU adaptation: Pallas stream kernels + TPU-ECM",
     tpu_stream_ecm),
    ("tpu_roofline", "TPU §Roofline: per (arch x shape x mesh) ECM terms",
     tpu_roofline),
    ("tpu_energy", "TPU Fig. 5/6 analogue: energy per step per cell",
     tpu_energy),
    ("tpu_scaling", "TPU Eq. 2 analogue: DP-scaling saturation per arch",
     tpu_scaling),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[s[0] for s in SECTIONS])
    args = ap.parse_args()
    for name, title, mod in SECTIONS:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"\n{'=' * 78}\n== {title}\n{'=' * 78}")
        print(mod.run())
        print(f"[{name}: {time.time() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
