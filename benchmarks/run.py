"""Run every benchmark: one section per paper table/figure, plus the TPU
adaptation (stream kernels + §Roofline table from the dry-run artifacts).

    PYTHONPATH=src python -m benchmarks.run [--only <name>]
    PYTHONPATH=src python -m benchmarks.run --json [BENCH_pipeline.json]

``--json`` skips the report sections and emits the perf-trajectory
artifact instead: per-kernel pipelined wall-clock (num_stages 1/2/3, the
fused triad->update chain) and model-eval throughput of the vectorized
``ECMBatch`` path vs the per-point scalar API, so future PRs can track
both hot paths.
"""
from __future__ import annotations

import argparse
import json
import time

from . import (
    fig10_scaling,
    fig11_bandwidth,
    fig12_nt_stores,
    fig56_energy,
    fig789_sweeps,
    stencil_sweep,
    table1_ecm,
    tpu_energy,
    tpu_roofline,
    tpu_scaling,
    tpu_stream_ecm,
)

SECTIONS = [
    ("table1_ecm", "Table I: ECM model vs paper predictions & measurements",
     table1_ecm),
    ("fig789_sweeps", "Figs. 7-9: working-set sweeps + AGU-optimized triad",
     fig789_sweeps),
    ("fig10_scaling", "Fig. 10: multicore scaling, CoD vs non-CoD (Eq. 2)",
     fig10_scaling),
    ("fig56_energy", "Figs. 5/6: energy-to-solution and EDP grids",
     fig56_energy),
    ("fig11_bandwidth", "Fig. 11: sustained bandwidth across uarchs",
     fig11_bandwidth),
    ("fig12_nt_stores", "Fig. 12: non-temporal stores (ECM vs roofline)",
     fig12_nt_stores),
    ("stencil_sweep",
     "Stencil LC-ECM: 2D Jacobi sweeps + blocking (arXiv:1410.5010)",
     stencil_sweep),
    ("tpu_stream_ecm", "TPU adaptation: Pallas stream kernels + TPU-ECM",
     tpu_stream_ecm),
    ("tpu_roofline", "TPU §Roofline: per (arch x shape x mesh) ECM terms",
     tpu_roofline),
    ("tpu_energy", "TPU Fig. 5/6 analogue: energy per step per cell",
     tpu_energy),
    ("tpu_scaling", "TPU Eq. 2 analogue: DP-scaling saturation per arch",
     tpu_scaling),
]


def model_eval_benchmark(n_sizes: int = 2000, n_cores: int = 64) -> dict:
    """Model-eval throughput: vectorized batch path vs per-point API calls.

    The batch path evaluates the full (9 kernels x n_sizes) working-set
    surface and the (9 kernels x n_cores) scaling surface in a handful of
    array ops; the scalar baseline calls the per-point API the way the
    pre-batch ``sweep()`` / ``simulate_scaling()`` did (subsampled and
    extrapolated, it is that slow).
    """
    import numpy as np

    from repro.core import BENCHMARKS
    from repro.simcache import (
        EVAL_COUNTERS,
        reset_counters,
        scaling_batch,
        simulate_level,
        simulate_working_set,
        sweep_batch,
    )

    names = tuple(BENCHMARKS)
    sizes = list(np.geomspace(16 * 1024, 256 * 1024 * 1024, n_sizes))

    reset_counters()
    t0 = time.perf_counter()
    _, surface = sweep_batch(names, sizes)
    _, scaling = scaling_batch(names, n_cores)
    dt_batch = time.perf_counter() - t0
    batch_points = int(surface.size + scaling.size)
    batch_array_evals = EVAL_COUNTERS["batch_array_evals"]

    # scalar baseline: one API call per (kernel, size) point; 4 levels per
    # call internally (the old sweep() shape).  Subsample, then extrapolate.
    sub = sizes[:: max(n_sizes // 20, 1)]
    t0 = time.perf_counter()
    for n in names:
        for s_ in sub:
            simulate_working_set(n, s_)
        for lv in range(4):
            simulate_level(n, lv)
    dt_sub = time.perf_counter() - t0
    scalar_points = len(names) * (len(sub) + 4)
    scalar_rate = scalar_points / dt_sub

    return {
        "batch_points": batch_points,
        "batch_wall_s": dt_batch,
        "batch_points_per_s": batch_points / dt_batch,
        "batch_array_evals": batch_array_evals,
        "python_calls_per_point_batch": batch_array_evals / batch_points,
        "scalar_points_per_s": scalar_rate,
        "python_calls_per_point_scalar": 1.0,
        "throughput_ratio": (batch_points / dt_batch) / scalar_rate,
        "per_point_call_reduction": batch_points / batch_array_evals,
    }


def autotune_rank_benchmark(n_chips: int = 4096) -> dict:
    """Candidate-ranking throughput of the vectorized autotuner."""
    from repro.core.autotune import WorkloadSpec, candidates, estimate, rank

    w = WorkloadSpec(n_params=9_000_000_000, d_model=4096, n_layers=40,
                     global_batch=4096, seq_len=4096)
    cands = candidates(n_chips, w)
    t0 = time.perf_counter()
    ranked = rank(w, n_chips)
    dt_batch = time.perf_counter() - t0
    t0 = time.perf_counter()
    for c in cands[: max(len(cands) // 4, 1)]:
        estimate(w, c)
    dt_scalar = (time.perf_counter() - t0) * 4
    return {
        "n_candidates": len(cands),
        "batch_rank_wall_s": dt_batch,
        "scalar_estimate_wall_s_extrapolated": dt_scalar,
        "best_config": ranked[0].summary() if ranked else None,
    }


def emit_json(path: str) -> None:
    from . import tpu_stream_ecm

    payload = {
        "pipeline": tpu_stream_ecm.pipeline_timings(rows=256, repeats=3),
        "model_eval": model_eval_benchmark(),
        "autotune": autotune_rank_benchmark(),
        "schema": 1,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    me = payload["model_eval"]
    print(f"[bench] wrote {path}: "
          f"{me['batch_points_per_s']:.0f} model points/s batch vs "
          f"{me['scalar_points_per_s']:.0f} scalar "
          f"({me['throughput_ratio']:.0f}x), "
          f"{me['per_point_call_reduction']:.0f}x fewer Python-level calls "
          f"per point")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[s[0] for s in SECTIONS])
    ap.add_argument("--json", nargs="?", const="BENCH_pipeline.json",
                    default=None, metavar="PATH",
                    help="emit the perf-trajectory JSON instead of the "
                         "report sections")
    args = ap.parse_args()
    if args.json:
        emit_json(args.json)
        return 0
    for name, title, mod in SECTIONS:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"\n{'=' * 78}\n== {title}\n{'=' * 78}")
        print(mod.run())
        print(f"[{name}: {time.time() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
