"""§Roofline: the per-(arch x shape x mesh) three-term ECM/roofline table,
read from the dry-run result JSONs (results/dryrun/*.json).

Terms per cell (seconds/step, per chip):

    compute    = HLO_FLOPs / (chips x 197e12)
    memory     = HLO_bytes / (chips x 819e9)
    collective = collective wire bytes / (chips x 50e9/link)

plus MODEL_FLOPS/HLO_FLOPs (useful-compute fraction) and the dominant term.
Run ``python -m repro.launch.dryrun --all`` first to (re)generate cells.
"""
from __future__ import annotations

import glob
import json
import os

from .util import fmt, table

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def roofline_rows(recs: list[dict]) -> list[list]:
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            rows.append([r["arch"], r["shape"], r["mesh"], "SKIP",
                         "-", "-", "-", "-", "-", "-", r["reason"][:38]])
            continue
        if r["status"] == "error":
            rows.append([r["arch"], r["shape"], r["mesh"], "ERROR",
                         "-", "-", "-", "-", "-", "-",
                         r["error"][:38]])
            continue
        e = r["ecm"]
        rows.append([
            r["arch"], r["shape"], r["mesh"], "ok",
            fmt(e["t_comp_s"] * 1e3, 2), fmt(e["t_hbm_s"] * 1e3, 2),
            fmt((e["t_ici_s"] + e["t_dcn_s"]) * 1e3, 2),
            e["dominant"][:4],
            fmt(e["useful_flops_fraction"], 3),
            fmt(e["roofline_fraction"], 3),
            fmt(r["peak_bytes_per_chip"] / 2**30, 1) + "GiB"
            + ("" if r.get("fits_hbm") else "!"),
        ])
    return rows


def run() -> str:
    out = []
    for mesh in ("16x16", "2x16x16"):
        recs = load_records(mesh)
        if not recs:
            out.append(f"== {mesh}: no dry-run records in {RESULTS} ==")
            continue
        out.append(f"== §Roofline, mesh {mesh} ({len(recs)} cells) ==")
        out.append(table(
            ["arch", "shape", "mesh", "st", "comp_ms", "hbm_ms", "coll_ms",
             "dom", "useful", "roofline", "mem/chip"],
            roofline_rows(recs)))
        ok = [r for r in recs if r["status"] == "ok"]
        if ok:
            worst = min(ok, key=lambda r: r["ecm"]["roofline_fraction"])
            coll = max(ok, key=lambda r: r["ecm"]["t_ici_s"] + r["ecm"]["t_dcn_s"])
            out.append(f"  worst roofline fraction: {worst['arch']} x "
                       f"{worst['shape']} ({worst['ecm']['roofline_fraction']:.3f})")
            out.append(f"  most collective-bound:  {coll['arch']} x "
                       f"{coll['shape']} "
                       f"({(coll['ecm']['t_ici_s']+coll['ecm']['t_dcn_s'])*1e3:.2f} ms)")
        out.append("")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
