"""``--suite mesh``: the multi-chip parallelism model + mesh autotuner.

Eq. 2 generalized from cores on a chip to chips on a mesh
(``repro.core.mesh``): for each pinned zoo config and chip count the
unified ``autotune.rank(config, machine, mesh=N)`` facade ranks every
(mesh shape, sharding profile, kernel block sizes) candidate jointly —
per-chip compute from the whole-model composition, per-strategy ICI
collective terms from the ring wire-byte math of ``repro.core.hlo``,
pipeline bubble over microbatch count — and the winner is golden-pinned
per (config, N) on tpu-v5e.

Three payload blocks:

* ``rankings`` — the pinned winners (mesh label, profile, factorization,
  attention block, step/ICI microseconds, saturation) per config x chip
  count; any drift is a modeling change the regression gate must see;
* ``dp_scaling`` — ``tpu_dp_scaling`` routed through the generalized
  ``mesh.dp_scaling`` path must stay **bit-identical** to the legacy
  arithmetic (the refactor's no-drift contract);
* ``sweep`` — warm-path throughput of the full (config x N x plan)
  sweep (the ``LoweredTable``-backed regime), floor-gated in CI.
"""
from __future__ import annotations

import time

#: the pinned (config, chip-count) grid — three zoo configs spanning the
#: profile families (dense TP+DP, dense TP+FSDP, MoE expert-parallel)
MESH_CONFIGS = ("internlm2-1.8b", "glm4-9b", "granite-moe-1b-a400m")
CHIP_COUNTS = (8, 16, 64)
BATCH = 8
SEQ_LEN = 2048

#: keys of one ranked row that are stable pins (no wall-clock content)
WINNER_KEYS = ("mesh", "profile", "data", "model", "pipe", "microbatches",
               "t_step_us", "t_ici_us", "bubble_fraction", "n_saturation",
               "fits_hbm")


def _winner(row: dict) -> dict:
    out = {k: row[k] for k in WINNER_KEYS}
    if row.get("block") is not None:
        out["block"] = list(row["block"])
    return out


def rankings_payload(machine: str = "tpu-v5e") -> dict:
    """The golden-pinned winners: one joint ranking per config x N."""
    from repro.core.autotune import rank

    out: dict[str, dict] = {}
    for cfg in MESH_CONFIGS:
        out[cfg] = {}
        for n in CHIP_COUNTS:
            rows = rank(cfg, machine, mesh=n, batch=BATCH, seq_len=SEQ_LEN)
            out[cfg][str(n)] = {"winner": _winner(rows[0]),
                                "n_plans": len(rows)}
    return out


def dp_scaling_payload() -> dict:
    """Bit-identity of the legacy DP path through the mesh model."""
    from repro.core.mesh import dp_scaling
    from repro.core.scaling import tpu_dp_scaling

    from .scaling_bench import _dp_resources

    res = _dp_resources()
    legacy = tpu_dp_scaling(res)
    new = dp_scaling(res)
    return {
        "bit_identical": legacy == new,
        "chips": new["chips"],
        "n_saturation": new["n_saturation"],
        "t_ici_floor_us": new["t_ici_floor_us"],
    }


def sweep_payload(machine: str = "tpu-v5e") -> dict:
    """Warm-path mesh-sweep throughput over the pinned grid (the second
    pass hits the request-path ``LoweredTable``, so this times the
    analytic collective + Eq. 2 evaluation, not lowering)."""
    from repro.core.autotune import rank

    plans = 0
    for cfg in MESH_CONFIGS:           # warm the composition/lowering path
        rank(cfg, machine, mesh=CHIP_COUNTS[0], batch=BATCH,
             seq_len=SEQ_LEN, include_blocks=False)
    t0 = time.perf_counter()
    for cfg in MESH_CONFIGS:
        for n in CHIP_COUNTS:
            plans += len(rank(cfg, machine, mesh=n, batch=BATCH,
                              seq_len=SEQ_LEN, include_blocks=False))
    dt = time.perf_counter() - t0
    return {
        "configs": len(MESH_CONFIGS),
        "chip_counts": list(CHIP_COUNTS),
        "plans": plans,
        "wall_s": dt,
        "plans_per_s": plans / dt,
    }


def mesh_payload(machine: str = "tpu-v5e") -> dict:
    """The ``BENCH_mesh.json`` payload body (envelope added by the
    runner)."""
    return {
        "rankings": rankings_payload(machine),
        "dp_scaling": dp_scaling_payload(),
        "sweep": sweep_payload(machine),
    }


def run(machine: str | None = None) -> str:
    """Human-readable report section."""
    machine = machine or "tpu-v5e"
    ranks = rankings_payload(machine)
    dp = dp_scaling_payload()
    lines = [f"mesh autotuner on {machine} "
             f"(batch={BATCH}, seq_len={SEQ_LEN}, train step):",
             f"{'config':<22} {'chips':>5} {'best mesh':<18} "
             f"{'profile':<8} {'t_step_ms':>10} {'bubble':>7} {'n_sat':>6}"]
    lines.append("-" * len(lines[-1]))
    for cfg, by_n in ranks.items():
        for n, cell in by_n.items():
            w = cell["winner"]
            sat = w["n_saturation"]
            lines.append(
                f"{cfg:<22} {n:>5} {w['mesh']:<18} {w['profile']:<8} "
                f"{w['t_step_us'] / 1e3:>10.1f} "
                f"{w['bubble_fraction']:>7.3f} "
                f"{sat if sat is not None else '-':>6}")
    lines.append(f"DP path bit-identical through mesh.dp_scaling: "
                 f"{dp['bit_identical']} "
                 f"(saturation ~{dp['n_saturation']} chips)")
    return "\n".join(lines)
