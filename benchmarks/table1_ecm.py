"""Paper Table I: microbenchmark ECM predictions vs measurement.

Three-way comparison per kernel and memory level:

* model      — ECM prediction built *from first principles* by
               ``repro.core.kernel_spec`` (port model + stream accounting);
* paper      — the paper's published prediction (regression target: must
               match `model` exactly);
* sim        — the calibrated cache-hierarchy simulator (this container's
               stand-in for the Haswell machine), vs the paper's measured
               cy/CL and the published error.
"""
from __future__ import annotations

from repro.core import (
    BENCHMARKS,
    PAPER_TABLE1_INPUTS,
    PAPER_TABLE1_MEASUREMENTS,
    PAPER_TABLE1_PREDICTIONS,
    haswell_ecm,
)
from repro.simcache import simulate_level

from .util import pred_str, table


def run() -> str:
    rows = []
    max_err = 0.0
    for name in BENCHMARKS:
        ecm = haswell_ecm(name)
        model = ecm.predictions()
        paper = PAPER_TABLE1_PREDICTIONS[name]
        sim = tuple(simulate_level(name, lv) for lv in range(4))
        meas = PAPER_TABLE1_MEASUREMENTS.get(name)
        model_ok = all(abs(m - p) < 0.05 for m, p in zip(model, paper))
        if meas:
            errs = tuple(abs(s - m) / m for s, m in zip(sim, meas))
            max_err = max(max_err, *errs)
            err_s = "{" + " ".join(f"{e*100:.0f}%" for e in errs) + "}"
        else:
            err_s = "-"
        rows.append([
            name, BENCHMARKS[name].expr,
            ecm.notation(), pred_str(model),
            "OK" if model_ok else f"MISMATCH {pred_str(paper)}",
            pred_str(sim), pred_str(meas) if meas else "-", err_s,
        ])
    hdr = ["kernel", "loop body", "ECM input (derived)", "prediction",
           "vs paper", "sim 'measurement'", "paper measured", "sim err"]
    out = [table(hdr, rows)]
    # derived inputs vs the paper's stated inputs: predictions must agree at
    # every level (T_OL/T_nOL bookkeeping may differ where max() absorbs it,
    # e.g. the update kernel — DESIGN.md §8.2)
    from repro.core import ECMModel
    input_ok = all(
        abs(a - b) < 0.05
        for n in BENCHMARKS
        for a, b in zip(ECMModel.parse(PAPER_TABLE1_INPUTS[n]).predictions(),
                        haswell_ecm(n).predictions())
    )
    out.append(f"\nderived inputs reproduce the paper's stated inputs "
               f"(prediction-equivalent at every level): {input_ok}")
    out.append(f"max simulator-vs-paper-measurement error: {max_err*100:.0f}% "
               "(paper's own model-vs-measurement errors reach 33%)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
