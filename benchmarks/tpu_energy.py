"""TPU analogue of the paper's Figs. 5/6 (energy-to-solution / EDP).

The paper's §III-D insight: once the shared bottleneck saturates, more
cores/frequency only cost energy.  On TPU the analogous knobs are chip
count and per-chip utilization.  Using the per-term energy model
(pJ/FLOP, pJ/HBM-byte, pJ/ICI-byte + idle power x ECM time) on the
dry-run records, this benchmark reports energy per step and the
energy-optimal chip count per (arch x shape): bandwidth-bound steps waste
energy on idle MXUs exactly like the Stream triad wasted cores.

Eq. 2 analogue: scaling chips divides compute/HBM terms but grows the
collective term; `saturation_chips` is where adding chips stops paying.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.machine import TPU_V5E

from .util import fmt, table

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def step_energy(rec: dict, m=TPU_V5E) -> dict:
    """Joules per step per chip from the recorded ECM terms."""
    e = rec["ecm"]
    chips = e["detail_chips"]
    flops = rec["cost"]["flops_per_chip"]
    hbm = rec["cost"]["bytes_per_chip"]
    ici = rec["collectives"]["wire_bytes_per_chip"]
    dyn = (flops * m.pj_per_flop + hbm * m.pj_per_hbm_byte
           + ici * m.pj_per_ici_byte) * 1e-12
    idle = m.idle_watts * e["t_ecm_s"]
    return {
        "dyn_J": dyn, "idle_J": idle, "total_J": dyn + idle,
        "fleet_kJ": (dyn + idle) * chips / 1e3,
        "idle_frac": idle / max(dyn + idle, 1e-12),
    }


def run() -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*16x16.json"))):
        if "2x16x16" in path:
            continue
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        en = step_energy(rec)
        e = rec["ecm"]
        rows.append([
            rec["arch"], rec["shape"],
            fmt(e["t_ecm_s"] * 1e3, 1),
            fmt(en["total_J"], 2), fmt(en["fleet_kJ"], 2),
            fmt(en["idle_frac"] * 100, 0) + "%",
            e["dominant"][:4],
        ])
    if not rows:
        return f"no dry-run records in {RESULTS}"
    out = [table(["arch", "shape", "step_ms", "J/chip/step",
                  "fleet kJ/step", "idle share", "dom"], rows)]
    out.append(
        "\npaper Fig. 5/6 lesson transferred: bandwidth/collective-bound "
        "steps have high idle share — the energy-optimal config uses fewer "
        "chips (or lower clock) for the same step, exactly the race-to-idle "
        "result at chip granularity.")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
