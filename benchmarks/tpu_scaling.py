"""Eq. 2 at chip granularity: DP-scaling saturation per architecture.

The paper: P(n) = min(n·P_ECM, I·b_S); cores beyond n_S = ceil(T_ECM /
T_bottleneck) don't help.  For a fixed global batch on TPU, adding chips
divides the compute and HBM terms but the collective term (gradient
reduction) approaches a floor — the ECM-predicted saturation chip count
is where the speedup curve flattens.  Derived analytically from the
autotuner's workload estimator for each assigned architecture.
"""
from __future__ import annotations

from repro.configs import ARCH_NAMES, get_arch
from repro.core.autotune import CandidateConfig, WorkloadSpec, estimate

from .util import fmt, table

CHIPS = (16, 32, 64, 128, 256, 512, 1024, 2048)


def _workload(arch) -> WorkloadSpec:
    cfg = arch.cfg
    return WorkloadSpec(
        n_params=arch.n_active_params,
        d_model=cfg.d_model,
        n_layers=getattr(cfg, "n_layers", 12),
        global_batch=256, seq_len=4096, kind="train")


def run() -> str:
    rows = []
    for name in ARCH_NAMES:
        arch = get_arch(name)
        w = _workload(arch)
        times = []
        for n in CHIPS:
            model = max(1, min(16, n // 16))
            data = n // model
            accum = max(1, w.global_batch // max(data, 1))
            accum = min(accum, 16)
            est = estimate(w, CandidateConfig(data=data, model=model,
                                              accum=accum))
            times.append(est.t_ecm)
        # parallel efficiency at the largest fleet vs the 16-chip baseline
        eff = times[0] * CHIPS[0] / (times[-1] * CHIPS[-1])
        rows.append([arch.name,
                     *(fmt(t * 1e3, 1) for t in times),
                     fmt(eff * 100, 0) + "%"])
    hdr = ["arch (train_4k)"] + [f"{n}c ms" for n in CHIPS] + ["eff@2048"]
    out = [table(hdr, rows)]
    out.append(
        "\nEq. 2 transferred: with a 1M-token global batch DP scales to 2k "
        "chips at 83-97% ECM efficiency; the gap is the Eq.-2 floor (the "
        "per-microbatch weight stream + gradient collective, which do not "
        "shrink with the data axis).  Small-batch serving saturates far "
        "earlier — see the decode rows of §Roofline, where per-chip work "
        "at 256 chips is already bandwidth-floor-bound.")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
