"""Paper Fig. 12 / §VII-E: non-temporal stores.

The roofline (bandwidth-only) model predicts 1.33x (Stream) / 1.25x
(Schönauer) from dropping the RFO stream; measurements show 1.40-1.42x /
1.32-1.33x.  The ECM model explains the surplus: NT stores also remove
in-cache write-allocate/evict traffic.  This benchmark reproduces the ECM
speedups *exactly* (1.42x / 1.32x, as inferred in the paper's text).
"""
from __future__ import annotations

from repro.core import haswell_ecm

from .util import fmt, pred_str, table

PAIRS = (("striad", "striad_nt", 4 / 3, 1.42),
         ("schoenauer", "schoenauer_nt", 5 / 4, 1.32))


def run() -> str:
    rows = []
    for reg, nt, roofline_x, paper_x in PAIRS:
        e_reg = haswell_ecm(reg)
        e_nt = haswell_ecm(nt)
        mem = len(e_reg.levels) - 1
        ecm_x = e_reg.prediction(mem) / e_nt.prediction(mem)
        rows.append([
            reg, pred_str(e_reg.predictions()), pred_str(e_nt.predictions()),
            fmt(roofline_x, 2), fmt(ecm_x, 2), fmt(paper_x, 2),
            "OK" if abs(ecm_x - paper_x) < 0.012 else "MISMATCH",
        ])
    return table(
        ["kernel", "ECM regular", "ECM non-temporal", "roofline x",
         "ECM x", "paper x", "check"],
        rows)


def main():
    print(run())


if __name__ == "__main__":
    main()
