"""Paper Figs. 7, 8, 9: cy/CL vs working-set size for all seven kernels,
ECM prediction (light-speed, per residence level) against the simulator's
"measurement" curve.  Fig. 9's right panel — the AGU-optimized Schönauer
triad (port-7 simple-AGU + LEA trick, §VII-C) — is included as
``schoenauer(opt-AGU)``: T_nOL drops from 4 to 3 cycles.
"""
from __future__ import annotations

from repro.core import haswell_ecm
from repro.simcache import HASWELL_CACHES_COD, simulate_working_set, sweep

from .util import fmt, pred_str, table

SIZES_KB = [16, 24, 32, 64, 128, 192, 256, 512, 1024, 4096, 8192, 16384,
            32768, 65536, 131072]

FIGS = {
    "fig7": ("load", "ddot"),
    "fig8": ("store", "update", "copy"),
    "fig9": ("striad", "schoenauer"),
}


def run() -> str:
    out = []
    for fig, kernels in FIGS.items():
        rows = []
        for kb in SIZES_KB:
            row = [kb]
            for k in kernels:
                row.append(fmt(simulate_working_set(k, kb * 1024), 1))
            rows.append(row)
        hdr = ["WS_KiB"] + [f"{k} sim" for k in kernels]
        out.append(f"== {fig}: working-set sweep (cy/CL) ==")
        out.append(table(hdr, rows))
        for k in kernels:
            out.append(f"  {k}: ECM prediction {pred_str(haswell_ecm(k).predictions())}")
        out.append("")

    # Fig. 9 right panel: naive vs AGU-optimized Schönauer
    naive = haswell_ecm("schoenauer")
    opt = haswell_ecm("schoenauer", optimized_agu=True)
    out.append("== fig9 (right): Schönauer triad, naive vs optimized AGU ==")
    out.append(f"  naive   T_nOL={naive.t_nol:.0f} cy -> {pred_str(naive.predictions())}")
    out.append(f"  opt-AGU T_nOL={opt.t_nol:.0f} cy -> {pred_str(opt.predictions())}")
    out.append(f"  L1 speedup {naive.prediction(0)/opt.prediction(0):.2f}x "
               "(paper: 8 addressing uops through 3 AGUs = 3 cy vs 4 cy)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
