"""Paper Figs. 7, 8, 9: cy/CL vs working-set size for all seven kernels,
ECM prediction (light-speed, per residence level) against the simulator's
"measurement" curve.  Fig. 9's right panel — the AGU-optimized Schönauer
triad (port-7 simple-AGU + LEA trick, §VII-C) — is included as
``schoenauer(opt-AGU)``: T_nOL drops from 4 to 3 cycles.

The whole (kernels x sizes) surface of each figure is one vectorized
``sweep_batch`` evaluation (the per-point scalar path used to cost
4 model builds per size per kernel).
"""
from __future__ import annotations

import time

from repro.core import haswell_ecm
from repro.simcache import EVAL_COUNTERS, sweep_batch

from .util import fmt, pred_str, table

SIZES_KB = [16, 24, 32, 64, 128, 192, 256, 512, 1024, 4096, 8192, 16384,
            32768, 65536, 131072]

FIGS = {
    "fig7": ("load", "ddot"),
    "fig8": ("store", "update", "copy"),
    "fig9": ("striad", "schoenauer"),
}


def run() -> str:
    out = []
    sizes = [kb * 1024 for kb in SIZES_KB]
    t0 = time.perf_counter()
    evals0 = EVAL_COUNTERS["batch_array_evals"]
    surfaces = {fig: sweep_batch(kernels, sizes)[1]
                for fig, kernels in FIGS.items()}
    dt = time.perf_counter() - t0
    n_points = sum(s.size for s in surfaces.values())
    n_evals = EVAL_COUNTERS["batch_array_evals"] - evals0

    for fig, kernels in FIGS.items():
        surface = surfaces[fig]
        rows = []
        for j, kb in enumerate(SIZES_KB):
            row = [kb] + [fmt(surface[i, j], 1) for i in range(len(kernels))]
            rows.append(row)
        hdr = ["WS_KiB"] + [f"{k} sim" for k in kernels]
        out.append(f"== {fig}: working-set sweep (cy/CL) ==")
        out.append(table(hdr, rows))
        for k in kernels:
            out.append(f"  {k}: ECM prediction {pred_str(haswell_ecm(k).predictions())}")
        out.append("")

    out.append(f"[batch eval: {n_points} (kernel x size) points in "
               f"{n_evals} array ops, {dt * 1e3:.2f} ms wall]")

    # Fig. 9 right panel: naive vs AGU-optimized Schönauer
    naive = haswell_ecm("schoenauer")
    opt = haswell_ecm("schoenauer", optimized_agu=True)
    out.append("== fig9 (right): Schönauer triad, naive vs optimized AGU ==")
    out.append(f"  naive   T_nOL={naive.t_nol:.0f} cy -> {pred_str(naive.predictions())}")
    out.append(f"  opt-AGU T_nOL={opt.t_nol:.0f} cy -> {pred_str(opt.predictions())}")
    out.append(f"  L1 speedup {naive.prediction(0)/opt.prediction(0):.2f}x "
               "(paper: 8 addressing uops through 3 AGUs = 3 cy vs 4 cy)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
