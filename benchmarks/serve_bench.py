"""``--suite serve``: the fault-tolerant serving engine under traffic.

One fixed heavy-traffic trace (seed 0) is replayed through the
continuous-batching engine once per fault class — ``none``,
``device_loss``, ``slow_step``, ``kv_corruption`` — and each run's
deterministic summary lands in ``BENCH_serve.json``: virtual-clock
throughput and latency percentiles, predicted-vs-measured step-time
ratios, recovery counts, the per-bucket KV blocks the autotuner chose,
and the full event-count ledger.  Everything except the wall-clock
key is bit-reproducible (virtual clock + seeded jitter), so the CI
regression gate compares the numbers exactly-ish (``--compare``)
against the committed baseline: a lost request, a changed recovery
sequence, or a drifted prediction fails the gate.
"""
from __future__ import annotations

import time

from repro.serve import (
    EngineConfig,
    FaultInjector,
    ServeEngine,
    TraceConfig,
    fault_plan,
    synthetic_trace,
)
from repro.serve.policy import DegradationPolicy

#: the bench fault matrix, one engine run per class
FAULT_CLASSES = ("none", "device_loss", "slow_step", "kv_corruption")

#: heavy traffic — tight arrivals so batches actually form and the
#: degradation ladder gets exercised under pressure
TRACE = TraceConfig(mean_interarrival_s=0.001)

#: step budget chosen below the largest-batch predicted step time so
#: degrade/restore transitions show up in the emitted log
DEGRADE = DegradationPolicy(step_budget_s=0.001)


def run_class(name: str, machine: str = "tpu-v5e", *,
              seed: int = 0) -> dict:
    """One engine run under fault class ``name``; returns the summary
    plus the chosen KV blocks and the (volatile) wall time."""
    engine = ServeEngine(EngineConfig(machine=machine, seed=seed),
                         degrade=DEGRADE)
    trace = synthetic_trace(TRACE, seed=seed)
    t0 = time.perf_counter()
    summary = engine.run(trace, FaultInjector(fault_plan(name)))
    summary["wall_s"] = time.perf_counter() - t0
    summary["blocks"] = {
        str(cb): blk for cb, blk in engine.buckets.chosen_blocks().items()}
    return summary


def serve_payload(machine: str = "tpu-v5e") -> dict:
    """The ``BENCH_serve.json`` payload body (envelope added by the
    runner)."""
    return {
        "trace": {
            "n_requests": TRACE.n_requests,
            "mean_interarrival_ms": TRACE.mean_interarrival_s * 1e3,
            "seed": 0,
        },
        "classes": {name: run_class(name, machine)
                    for name in FAULT_CLASSES},
    }


def run(machine: str | None = None) -> str:
    """Human-readable report section."""
    machine = machine or "tpu-v5e"
    lines = [f"fault-tolerant serving on {machine} "
             f"({TRACE.n_requests} requests, "
             f"{TRACE.mean_interarrival_s * 1e3:.1f} ms mean interarrival)",
             "",
             f"{'fault class':<14} {'done':>5} {'lost':>5} {'tok/s':>9} "
             f"{'p50 ms':>8} {'p99 ms':>8} {'requeue':>8} {'maxlvl':>7} "
             f"{'max m/p':>8}"]
    for name in FAULT_CLASSES:
        s = run_class(name, machine)
        p50 = s["latency_p50"] * 1e3 if s["latency_p50"] else float("nan")
        p99 = s["latency_p99"] * 1e3 if s["latency_p99"] else float("nan")
        lines.append(
            f"{name:<14} {s['completed']:>5} {s['lost']:>5} "
            f"{s['tok_rate']:>9.0f} {p50:>8.2f} {p99:>8.2f} "
            f"{s['recovery']['requeued']:>8} {s['degrade_max_level']:>7} "
            f"{s['step_pred_measured']['max_ratio']:>8.2f}")
    lines.append("")
    lines.append("every admission/degradation/shed decision in the event "
                 "log carries the ECM prediction that triggered it; "
                 "lost == requests with no terminal state (must be 0)")
    return "\n".join(lines)
