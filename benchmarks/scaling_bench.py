"""Chip-level scaling + energy suite: Fig. 10 (Eq. 2 saturation, CoD vs
non-CoD), Figs. 5/6 (energy-to-solution / EDP grids and the
energy-optimal operating point) and the TPU data-parallel Eq. 2 analogue
(ICI collectives as the shared bottleneck) — all through the one
registry engine (``repro.core.scaling``), for any ``--machine``.

Merges the former ``fig10_scaling`` / ``fig56_energy`` / ``tpu_scaling``
/ ``tpu_energy`` sections; the ``--json`` payload is the ``scaling``
suite's ``BENCH_scaling.json`` (schema 2 envelope, validated and
regression-gated by ``tools/check_bench.py``).
"""
from __future__ import annotations

from .util import fmt, table

#: the Fig. 10 kernels (plus the compute-bound families, which exercise
#: the core-bound n_S = cores path)
FIG10_KERNELS = ("ddot", "striad", "schoenauer")
DATASET_BYTES = 10e9


def _work_units(machine) -> float:
    """Fig. 5/6 normalization: CLs of the A array of a 10 GB striad set."""
    return DATASET_BYTES / 3 / machine.line_bytes


def saturation_payload(machine: str = "haswell-ep") -> dict:
    """Eq. 2 for every registered workload on one machine, plus the
    Haswell-style CoD vs non-CoD comparison for the Fig. 10 kernels.
    The per-workload rows come from the engine's one shared extraction
    (:meth:`repro.core.scaling.ChipScaling.saturation_summary`)."""
    from repro.core import get_machine, scale_workloads, workload_registry
    from repro.core.machine import HASWELL_CHIP_BW_NONCOD
    from repro.core.workload import StreamWorkload
    from repro.core.kernel_spec import BENCHMARKS

    m = get_machine(machine)
    cs = scale_workloads(list(workload_registry().values()), m)
    out = {
        "workloads": cs.saturation_summary(),
        "cores_per_domain": cs.cores_per_domain,
        "n_domains": cs.n_domains,
    }
    if m.name == "haswell-ep":
        # Fig. 10's second mode: one big domain at the chip bandwidth
        noncod = {}
        for k in FIG10_KERNELS:
            nc = scale_workloads(
                [StreamWorkload(BENCHMARKS[k])], m,
                sustained_bw=HASWELL_CHIP_BW_NONCOD[k],
                cores_per_domain=m.cores, n_domains=1)
            noncod[k] = nc.saturation_summary()[k]["n_sat_domain"]
        out["fig10_noncod"] = noncod
    return out


def energy_payload(machine: str = "haswell-ep",
                   workload: str = "striad") -> dict:
    """Figs. 5/6 from the machine's DVFS + power calibration: the energy
    and EDP grids plus both optimal operating points."""
    from repro.core import get_machine, scale_workloads, workload_registry

    m = get_machine(machine)
    w = workload_registry()[workload]
    cs = scale_workloads([w], m)
    work = _work_units(m)
    g = cs.energy(work)

    def _best(objective):
        b = cs.best(work, objective=objective)[0]
        return {"f_ghz": b["f_ghz"], "n_cores": b["n_cores"],
                "energy_J": b["energy_J"], "edp_Js": b["edp_Js"]}

    return {
        "workload": workload,
        "f_ghz": [float(f) for f in cs.f_ghz],
        "n_cores": cs.cores,
        "grid_energy_J": [[float(x) for x in row] for row in g["energy_J"][0]],
        "grid_edp_Js": [[float(x) for x in row] for row in g["edp_Js"][0]],
        "best_energy": _best("energy"),
        "best_edp": _best("edp"),
    }


def operating_points_payload(machine: str = "haswell-ep",
                             top: int = 5) -> list[dict]:
    """Top EDP operating points across the Fig. 10 kernels — the
    ``rank(..., objective="edp")`` path exercised end to end."""
    from repro.core import get_machine, workload_registry
    from repro.core.autotune import rank

    m = get_machine(machine)
    reg = workload_registry()
    ws = [reg[k] for k in FIG10_KERNELS if k in reg]
    return rank(ws, m, objective="edp",
                total_work_units=_work_units(m), top=top)


def _dp_resources(n_params: float = 1e9, tokens: float = 1 << 20,
                  dtype_bytes: int = 2):
    """First-order single-chip resources of one data-parallel training
    step: FLOPs/HBM from the usual 6ND counting, the gradient exchange
    as a real ``CollectiveOp`` so the ring wire-byte math of
    ``repro.core.hlo`` is what the scaling sees."""
    from repro.core.hlo import CollectiveOp, HLOResources

    res = HLOResources()
    res.flops = 6.0 * n_params * tokens
    # weights + grads + optimizer streamed once, activations ~3x fwd
    res.bytes_accessed = (3 * n_params * 4.0
                          + 3 * tokens * 4096 * dtype_bytes * 12)
    res.collectives = [CollectiveOp(kind="all-reduce",
                                    out_bytes=n_params * 4.0,
                                    group_size=1)]
    res.collective_out_bytes = res.by_kind()
    return res


def tpu_dp_payload(chip_counts=(1, 2, 4, 8, 16, 32, 64, 128, 256)) -> dict:
    """Eq. 2 at chip granularity: the gradient all-reduce's ICI wire
    floor is the shared bottleneck of data-parallel scaling."""
    from repro.core import tpu_dp_scaling

    return {"model": {"n_params": 1e9, "tokens": float(1 << 20)},
            **tpu_dp_scaling(_dp_resources(), chip_counts)}


def step_energy(rec: dict, m=None) -> dict:
    """Joules per step per chip from recorded dry-run ECM terms: the
    per-term energy model (pJ/FLOP, pJ/HBM-byte, pJ/ICI-byte + idle
    power x ECM time) — bandwidth-bound steps waste energy on idle MXUs
    exactly like the Stream triad wasted cores (§III-D transferred)."""
    from repro.core.machine import TPU_V5E

    m = m or TPU_V5E
    e = rec["ecm"]
    flops = rec["cost"]["flops_per_chip"]
    hbm = rec["cost"]["bytes_per_chip"]
    ici = rec["collectives"]["wire_bytes_per_chip"]
    dyn = (flops * m.pj_per_flop + hbm * m.pj_per_hbm_byte
           + ici * m.pj_per_ici_byte) * 1e-12
    idle = m.idle_watts * e["t_ecm_s"]
    return {
        "dyn_J": dyn, "idle_J": idle, "total_J": dyn + idle,
        "fleet_kJ": (dyn + idle) * e["detail_chips"] / 1e3,
        "idle_frac": idle / max(dyn + idle, 1e-12),
    }


# ---------------------------------------------------------------------------
# Report sections
# ---------------------------------------------------------------------------


def _saturation_section(machine: str) -> str:
    pay = saturation_payload(machine)
    rows = [[w, d["n_sat_domain"], d["n_sat_chip"],
             "core" if d["core_bound"] else "mem",
             fmt(d["t_single_cy"], 1), fmt(d["bottleneck_cy"], 2)]
            for w, d in pay["workloads"].items()]
    out = [f"== {machine}: Eq. 2 saturation "
           f"({pay['n_domains']} x {pay['cores_per_domain']} cores) ==",
           table(["workload", "n_sat/domain", "n_sat/chip", "bound",
                  "T_ECM^mem cy", "T_bottleneck cy"], rows)]
    if "fig10_noncod" in pay:
        rows = [[k, pay["workloads"][k]["n_sat_domain"], nc]
                for k, nc in pay["fig10_noncod"].items()]
        out.append("\nFig. 10 CoD (per 7-core domain) vs non-CoD "
                   "(chip bandwidth):")
        out.append(table(["kernel", "CoD n_sat", "non-CoD n_sat"], rows))
        out.append("paper: both modes saturate at nearly identical chip "
                   "performance; CoD needs n_domains x n_sat cores")
    return "\n".join(out)


def _energy_section(machine: str) -> str:
    pay = energy_payload(machine)
    freqs = pay["f_ghz"]
    out = [f"== {machine}: energy-to-solution [J] for "
           f"{pay['workload']} (rows = GHz, cols = cores) =="]
    out.append(table(
        ["GHz\\n"] + [str(n) for n in range(1, pay["n_cores"] + 1)],
        [[f] + [fmt(v, 0) for v in row]
         for f, row in zip(freqs, pay["grid_energy_J"])]))
    be, bd = pay["best_energy"], pay["best_edp"]
    out.append(f"best energy: {be['energy_J']:.0f} J at {be['f_ghz']} GHz "
               f"x {be['n_cores']} cores")
    out.append(f"best EDP:    {bd['edp_Js']:.1f} Js at {bd['f_ghz']} GHz "
               f"x {bd['n_cores']} cores")
    return "\n".join(out)


def _tpu_section() -> str:
    pay = tpu_dp_payload()
    rows = [[n, fmt(c, 1), fmt(h, 1), fmt(i, 1), fmt(t, 1),
             fmt(s, 2), fmt(e * 100, 0) + "%"]
            for n, c, h, i, t, s, e in zip(
                pay["chips"], pay["t_comp_us"], pay["t_hbm_us"],
                pay["t_ici_us"], pay["t_step_us"], pay["speedup"],
                pay["parallel_efficiency"])]
    out = ["== TPU Eq. 2 analogue: data-parallel scaling, 1B params x "
           "1M tokens ==",
           table(["chips", "comp us", "hbm us", "ici us", "step us",
                  "speedup", "eff"], rows),
           f"\nICI floor {fmt(pay['t_ici_floor_us'], 1)} us -> Eq. 2 "
           f"saturation at ~{pay['n_saturation']} chips (the gradient "
           f"ring's wire bytes stop shrinking — the T_L3Mem role at "
           f"chip granularity)"]
    return "\n".join(out)


def _arch_dp_section(chip_counts=(16, 32, 64, 128, 256, 512, 1024, 2048)
                     ) -> str:
    """DP-scaling saturation per assigned architecture (the former
    ``tpu_scaling`` section): for a fixed global batch, adding chips
    divides compute/HBM but the gradient collective approaches a floor —
    the ECM-predicted saturation is where the speedup flattens."""
    from repro.configs import ARCH_NAMES, get_arch
    from repro.core.autotune import CandidateConfig, WorkloadSpec, estimate

    rows = []
    for name in ARCH_NAMES:
        arch = get_arch(name)
        cfg = arch.cfg
        w = WorkloadSpec(
            n_params=arch.n_active_params, d_model=cfg.d_model,
            n_layers=getattr(cfg, "n_layers", 12),
            global_batch=256, seq_len=4096, kind="train")
        times = []
        for n in chip_counts:
            model = max(1, min(16, n // 16))
            data = n // model
            accum = min(max(1, w.global_batch // max(data, 1)), 16)
            est = estimate(w, CandidateConfig(data=data, model=model,
                                              accum=accum))
            times.append(est.t_ecm)
        eff = times[0] * chip_counts[0] / (times[-1] * chip_counts[-1])
        rows.append([arch.name, *(fmt(t * 1e3, 1) for t in times),
                     fmt(eff * 100, 0) + "%"])
    hdr = (["arch (train_4k)"] + [f"{n}c ms" for n in chip_counts]
           + [f"eff@{chip_counts[-1]}"])
    return "\n".join([
        "== per-arch DP scaling (autotuner estimates, Eq. 2 floor) ==",
        table(hdr, rows),
        "the efficiency gap is the Eq.-2 floor: per-microbatch weight "
        "stream + gradient collective do not shrink with the data axis"])


def _dryrun_energy_section() -> str:
    """Energy per step per chip from dry-run records, when present (the
    former ``tpu_energy`` section); empty string otherwise."""
    import glob
    import json
    import os

    results = os.environ.get("DRYRUN_RESULTS", "results/dryrun")
    rows = []
    for path in sorted(glob.glob(os.path.join(results, "*16x16.json"))):
        if "2x16x16" in path:
            continue
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        en = step_energy(rec)
        e = rec["ecm"]
        rows.append([
            rec["arch"], rec["shape"], fmt(e["t_ecm_s"] * 1e3, 1),
            fmt(en["total_J"], 2), fmt(en["fleet_kJ"], 2),
            fmt(en["idle_frac"] * 100, 0) + "%", e["dominant"][:4]])
    if not rows:
        return ""
    return "\n".join([
        "== TPU Fig. 5/6 analogue: energy per step (dry-run records) ==",
        table(["arch", "shape", "step_ms", "J/chip/step", "fleet kJ/step",
               "idle share", "dom"], rows),
        "bandwidth/collective-bound steps have high idle share — the "
        "energy-optimal config uses fewer chips for the same step "
        "(race-to-idle at chip granularity)"])


def run(machine: str | None = None) -> str:
    from repro.core import machine_names

    machines = [machine] if machine else ["haswell-ep", "sandy-bridge-ep"]
    out = []
    for m in machines:
        out.append(_saturation_section(m))
        out.append("")
        out.append(_energy_section(m))
        out.append("")
    if machine is None or machine in ("haswell-ep",):
        # the cross-uarch §III-D claim, now from per-machine calibration
        from repro.core import get_machine, scale_workloads, workload_registry

        pts = {}
        for m in ("haswell-ep", "sandy-bridge-ep"):
            mm = get_machine(m)
            cs = scale_workloads([workload_registry()["striad"]], mm)
            pts[m] = cs.best(_work_units(mm), objective="energy")[0]
        ratio = (pts["sandy-bridge-ep"]["energy_J"]
                 / pts["haswell-ep"]["energy_J"])
        out.append(f"haswell-ep vs sandy-bridge-ep optimal energy: "
                   f"{ratio:.2f}x better on Haswell "
                   f"(paper: 12-23% energy, 35-55% EDP)")
        out.append("")
    out.append(_tpu_section())
    out.append("")
    out.append(_arch_dp_section())
    dryrun = _dryrun_energy_section()
    if dryrun:
        out.append("")
        out.append(dryrun)
    out.append(f"\n[registered machines: {', '.join(machine_names())}; "
               f"run with --machine <m> for any of them]")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
