"""Cross-generation prediction tables: every registered workload on every
registered machine through the one unified engine (the arXiv:1702.07554
structure — same workload inputs, many machines — applied to the whole
workload registry).

    PYTHONPATH=src python -m benchmarks.run --only machine_zoo
    PYTHONPATH=src python -m benchmarks.run --only machine_zoo --machine skylake-sp

The memory-level ``T_ECM`` column is the headline (cy per unit of work:
cache line on the CPUs, 128-lane row on the TPU); the full per-level
prediction notation is shown per machine.  Note how the Skylake-SP victim
L3 and the TPU's no-write-allocate hierarchy change the *traffic routing*
of the same logical workload, not just the bandwidth numbers.
"""
from __future__ import annotations

from .util import fmt, pred_str, table


def zoo_payload(machines=None) -> dict:
    """{machine: {workload: {"levels", "predictions", "t_ecm_mem"}}}."""
    from repro.core import zoo_predictions

    out: dict = {}
    for mach, rows in zoo_predictions(machines=machines).items():
        out[mach] = {
            name: {
                "levels": list(levels),
                "predictions": [float(x) for x in preds],
                "t_ecm_mem": float(preds[-1]),
            }
            for name, (levels, preds) in rows.items()
        }
    return out


def saturation_zoo_payload(machines=None) -> dict:
    """The cross-zoo Eq. 2 table (every registered machine x every
    registered workload): per-domain and per-chip saturation points
    through the registry scaling engine."""
    from repro.core import saturation_table

    return saturation_table(machines=machines)


def run(machine: str | None = None) -> str:
    from repro.core import get_machine, machine_names

    # resolve aliases once: payloads key by canonical machine name
    machines = ([get_machine(machine).name] if machine
                else list(machine_names()))
    payload = zoo_payload(machines)
    out = []

    # headline grid: workloads x machines, memory-level T_ECM
    names = list(next(iter(payload.values())))
    rows = []
    for n in names:
        rows.append([n] + [fmt(payload[m][n]["t_ecm_mem"], 1)
                           for m in machines])
    out.append("== T_ECM at the memory level (cy per unit of work) ==")
    out.append(table(["workload"] + machines, rows))

    # cross-zoo Eq. 2: saturation points per (workload x machine) — the
    # chip-level story of the same registry grid (core-bound families
    # report the full chip: they never hit the shared bottleneck)
    sat = saturation_zoo_payload(machines)
    rows = []
    for n in names:
        rows.append([n] + [
            f"{sat[m][n]['n_sat_chip']}"
            + ("*" if sat[m][n]["core_bound"] else "")
            for m in machines])
    out.append("\n== Eq. 2 chip saturation points "
               "(* = core-bound: linear to the full chip) ==")
    out.append(table(["workload"] + machines, rows))

    # per-machine detail: full prediction notation
    for m in machines:
        mm = get_machine(m)
        out.append(f"\n== {m}: {{{' ] '.join(mm.level_names())}}} "
                   f"predictions ==")
        rows = [[n, pred_str(payload[m][n]["predictions"])] for n in names]
        out.append(table(["workload", "T_ECM"], rows))
    return "\n".join(out)


def main() -> int:
    print(run())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
