"""Stencil working-set / blocking sweeps (the arXiv:1410.5010 Fig. 6 shape).

Measured-vs-predicted cycles per cache-line update for the 2D 5-point
Jacobi as the problem size sweeps the working set from L1-resident to
memory-resident, with the layer-condition analysis switching the per-edge
stream counts along the way; plus a spatial-blocking sweep at a fixed
memory-resident size, ranked by the ECM autotuner, and wall-clock /
bit-equality validation of the Pallas stencil kernels across pipeline
depths.  Every payload accepts a registry ``machine`` (layer conditions
move with the machine's capacities; bandwidths with its calibration).

This module is a *section* of the merged suite runner — registration and
artifact emission live in ``benchmarks/run.py``:

    PYTHONPATH=src python -m benchmarks.run --suite stencil [--machine M]
    PYTHONPATH=src python -m benchmarks.run --json --suite stencil

The legacy CLI (``python -m benchmarks.stencil_sweep [--json]``) keeps
working and delegates to the merged runner.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .util import fmt, pred_str, table

#: problem widths N (square N x N grids, two f64 arrays in the model);
#: chosen to land working sets in L1 / L2 / L3 / Mem and to straddle the
#: L1 (N ~ 682) and L2 (N ~ 5461) layer-condition breaks.
SWEEP_NS = [32, 64, 128, 512, 1024, 2048, 4096, 8192]
BLOCK_N = 8192                     # memory-resident blocking showcase
LEVEL_NAMES = ("L1", "L2", "L3", "Mem")


def sweep_payload(ns=SWEEP_NS, machine: str | None = None) -> list[dict]:
    """Predicted and simulated-measured cy/CL-update per problem size."""
    from repro.simcache import stencil_sweep_batch

    machine = machine or "haswell-ep"
    r = stencil_sweep_batch("jacobi2d", ns, machine=machine)
    out = []
    for i, n in enumerate(r["n"]):
        out.append({
            "n": int(n),
            "ws_kib": float(r["ws_bytes"][i] / 1024),
            "regime": LEVEL_NAMES[int(r["regime"][i])],
            "lc_misses": [int(x) for x in r["misses"][i]],
            "predicted_cy_per_cl": float(r["predicted"][i]),
            "measured_cy_per_cl": float(r["measured"][i]),
            "model_error": float(r["measured"][i] / r["predicted"][i] - 1),
        })
    return out


def blocking_payload(n=BLOCK_N, machine: str | None = None) -> dict:
    """ECM-ranked spatial blockings at a memory-resident problem size."""
    from repro.core import get_machine
    from repro.core.autotune import rank

    ranked = rank(
        "jacobi2d", get_machine(machine or "haswell-ep"), widths=(n,))
    return {"n": n, "ranked": ranked, "best": ranked[0]}


def kernel_payload(size=(128, 96), repeats=2) -> dict:
    """Bit-equality + wall-clock of the Pallas 2D Jacobi across depths."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.stencil import ops, ref

    a = jax.random.normal(jax.random.key(0), size, jnp.float32)
    want = np.asarray(ref.jacobi2d(a))
    out: dict = {"shape": list(size), "stages": {}}
    for ns in (None, 1, 2, 3):
        fn = lambda: ops.jacobi2d(a, num_stages=ns, interpret=True)
        got = np.asarray(jax.block_until_ready(fn()))        # compile+check
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        out["stages"][str(ns)] = {
            "bit_identical_to_ref": bool(np.array_equal(got, want)),
            "wall_s": best,
        }
    return out


def run(machine: str | None = None) -> str:
    from repro.core import get_machine, stencil_ecm

    m = get_machine(machine or "haswell-ep")
    out = []
    rows = []
    for p in sweep_payload(machine=m.name):
        rows.append([p["n"], fmt(p["ws_kib"], 0) + " KiB", p["regime"],
                     "/".join(str(m) for m in p["lc_misses"]),
                     fmt(p["predicted_cy_per_cl"], 1),
                     fmt(p["measured_cy_per_cl"], 1),
                     f"{p['model_error']:+.1%}"])
    out.append(table(
        ["N", "working set", "regime", "LC misses L1/L2/L3",
         "ECM cy/CL", "sim cy/CL", "err"], rows))

    m_small = stencil_ecm("jacobi2d", widths=(SWEEP_NS[0],), machine=m)
    m_big = stencil_ecm("jacobi2d", widths=(BLOCK_N,), machine=m)
    out.append(
        f"\nlayer conditions move the model inputs, not just the residence "
        f"level:\n  N={SWEEP_NS[0]:>5}: {m_small.notation()} -> "
        f"{pred_str(m_small.predictions())}\n  N={BLOCK_N:>5}: "
        f"{m_big.notation()} -> {pred_str(m_big.predictions())}")

    b = blocking_payload(machine=m.name)
    brows = [[str(r["block"][0]), r["misses_l1"], fmt(r["t_ecm"], 1),
              fmt(r["speedup_vs_unblocked"], 2) + "x"]
             for r in sorted(b["ranked"], key=lambda r: r["block"])]
    out.append(f"\n== spatial blocking at N={b['n']} (memory-resident), "
               "ECM-ranked ==")
    out.append(table(["block width", "L1 misses", "T_ECM(Mem) cy/CL",
                      "speedup"], brows))
    out.append(f"autotuner pick: block {b['best']['block']} "
               f"({b['best']['speedup_vs_unblocked']:.2f}x predicted)")

    k = kernel_payload()
    krows = [[ns, "yes" if v["bit_identical_to_ref"] else "NO",
              fmt(v["wall_s"] * 1e3, 1)]
             for ns, v in k["stages"].items()]
    out.append(f"\n== Pallas 2D Jacobi {tuple(k['shape'])} vs ref.py "
               "(interpret mode) ==")
    out.append(table(["num_stages", "bit-identical", "wall ms"], krows))
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_stencil.json",
                    default=None, metavar="PATH",
                    help="emit the stencil perf-trajectory JSON (delegates "
                         "to benchmarks.run --suite stencil)")
    ap.add_argument("--machine", default=None,
                    help="registry machine (see repro.core.MACHINES)")
    args = ap.parse_args()
    if args.json:
        from . import run as run_mod

        run_mod.emit_json(args.json, suite="stencil", machine=args.machine)
        return 0
    print(run(machine=args.machine))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
