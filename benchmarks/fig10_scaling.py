"""Paper Fig. 10: multi-core scaling in CoD vs non-CoD mode (MUp/s) for
dot product, Stream triad and Schönauer triad, with Eq. 2 saturation
points.  The paper's observation reproduced: both modes saturate at nearly
identical chip performance; CoD saturates each 7-core memory domain with
~4 cores (2x4 cores for the chip = same count as non-CoD's 8)."""
from __future__ import annotations

import time

from repro.core import BENCHMARKS, benchmark_batch
from repro.core.machine import HASWELL_CHIP_BW_NONCOD
from repro.core.saturation import batch_saturation
from repro.simcache import scaling_batch

from .util import fmt, table

KERNELS = ("ddot", "striad", "schoenauer")


def run() -> str:
    out = []
    # both modes for all kernels: vectorized (K x cores) evaluations
    from repro.simcache import EVAL_COUNTERS

    t0 = time.perf_counter()
    evals0 = EVAL_COUNTERS["batch_array_evals"]
    _, cod = scaling_batch(KERNELS, 14, fill_domains_first=True)
    _, noncod = scaling_batch(
        KERNELS, 14,
        domain_bw={k: HASWELL_CHIP_BW_NONCOD[k] for k in KERNELS},
        cores_per_domain=14, n_domains=1, fill_domains_first=False)
    n_sat = batch_saturation(benchmark_batch(KERNELS))
    dt = time.perf_counter() - t0
    n_evals = EVAL_COUNTERS["batch_array_evals"] - evals0

    rows = []
    for i, name in enumerate(KERNELS):
        rows.append([
            name,
            int(n_sat[i]),
            fmt(cod[i, 3] / 1e6, 0), fmt(cod[i, -1] / 1e6, 0),
            fmt(noncod[i, 7] / 1e6, 0), fmt(noncod[i, -1] / 1e6, 0),
            fmt(cod[i, -1] / noncod[i, -1], 3),
        ])
    out.append(table(
        ["kernel", "n_sat/domain (Eq.2)", "CoD P(4) MUp/s", "CoD P(14)",
         "nonCoD P(8)", "nonCoD P(14)", "CoD/nonCoD"],
        rows))
    out.append("\nper-core scaling curve (ddot, MUp/s):")
    out.append(table(["cores", "CoD", "non-CoD"],
                     [[n + 1, fmt(c / 1e6, 0), fmt(nc / 1e6, 0)]
                      for n, (c, nc) in enumerate(zip(cod[0], noncod[0]))]))
    out.append(f"\n[batch eval: {cod.size + noncod.size} (kernel x cores) "
               f"points in {n_evals} array ops, {dt * 1e3:.2f} ms wall]")
    out.append("paper: ddot saturates slightly above 4000 MUp/s (CoD), "
               "slightly below (non-CoD)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
