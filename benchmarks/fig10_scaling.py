"""Paper Fig. 10: multi-core scaling in CoD vs non-CoD mode (MUp/s) for
dot product, Stream triad and Schönauer triad, with Eq. 2 saturation
points.  The paper's observation reproduced: both modes saturate at nearly
identical chip performance; CoD saturates each 7-core memory domain with
~4 cores (2x4 cores for the chip = same count as non-CoD's 8)."""
from __future__ import annotations

from repro.core import BENCHMARKS, HASWELL_EP, HASWELL_MEASURED_BW, haswell_ecm
from repro.core.machine import HASWELL_CHIP_BW_NONCOD
from repro.core.saturation import ScalingModel
from repro.simcache import simulate_scaling

from .util import fmt, table

KERNELS = ("ddot", "striad", "schoenauer")


def run() -> str:
    out = []
    rows = []
    for name in KERNELS:
        spec = BENCHMARKS[name]
        upd = spec.elems_per_line(64) * spec.updates_per_elem
        ecm_cod = haswell_ecm(name)
        sat = ScalingModel.from_ecm(ecm_cod)
        cod = simulate_scaling(name, 14, fill_domains_first=True)
        noncod = simulate_scaling(
            name, 14, domain_bw=HASWELL_CHIP_BW_NONCOD[name],
            cores_per_domain=14, n_domains=1, fill_domains_first=False)
        rows.append([
            name,
            sat.n_saturation,
            fmt(cod[3] / 1e6, 0), fmt(cod[-1] / 1e6, 0),
            fmt(noncod[7] / 1e6, 0), fmt(noncod[-1] / 1e6, 0),
            fmt(cod[-1] / noncod[-1], 3),
        ])
    out.append(table(
        ["kernel", "n_sat/domain (Eq.2)", "CoD P(4) MUp/s", "CoD P(14)",
         "nonCoD P(8)", "nonCoD P(14)", "CoD/nonCoD"],
        rows))
    out.append("\nper-core scaling curve (ddot, MUp/s):")
    cod = simulate_scaling("ddot", 14)
    noncod = simulate_scaling("ddot", 14,
                              domain_bw=HASWELL_CHIP_BW_NONCOD["ddot"],
                              cores_per_domain=14, n_domains=1,
                              fill_domains_first=False)
    out.append(table(["cores", "CoD", "non-CoD"],
                     [[n + 1, fmt(c / 1e6, 0), fmt(nc / 1e6, 0)]
                      for n, (c, nc) in enumerate(zip(cod, noncod))]))
    out.append("\npaper: ddot saturates slightly above 4000 MUp/s (CoD), "
               "slightly below (non-CoD)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
