"""Tiny text-table helpers shared by the benchmark scripts."""
from __future__ import annotations


def fmt(x, nd=1):
    if x is None:
        return "-"
    if isinstance(x, str):
        return x
    r = round(float(x), nd)
    if abs(r - round(r)) < 1e-9:
        return str(int(round(r)))
    return f"{r:.{nd}f}"


def table(headers: list[str], rows: list[list], widths=None) -> str:
    cols = len(headers)
    widths = widths or [
        max(len(str(headers[c])), *(len(str(r[c])) for r in rows)) + 2
        for c in range(cols)
    ]
    def line(cells):
        return "".join(str(c).ljust(w) for c, w in zip(cells, widths)).rstrip()
    out = [line(headers), line(["-" * (w - 2) for w in widths])]
    out += [line(r) for r in rows]
    return "\n".join(out)


def pred_str(t):
    return "{" + " ] ".join(fmt(x) for x in t) + "}"
