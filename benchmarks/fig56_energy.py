"""Paper Figs. 5/6: energy-to-solution and EDP over (frequency x cores) for
the Stream triad (10 GB dataset), on Haswell (bandwidth frequency-
independent) vs Sandy/Ivy-Bridge-style (bandwidth frequency-coupled).

Reproduced structure: race-to-idle is not energy-optimal; on Haswell the
lowest frequency minimises energy once bandwidth saturates; EDP optima sit
at moderate frequencies; Haswell beats SNB/IVB on both metrics.
"""
from __future__ import annotations

from repro.core import haswell_ecm
from repro.core.energy import FrequencyScaledECM, PowerModel, best_config, energy_grid

from .util import fmt, table

FREQS = [1.2, 1.6, 2.0, 2.3, 2.7, 3.0]
DATASET_BYTES = 10e9
# striad moves 4 CLs per 64 B of A-array work -> work units = CLs of A
WORK_UNITS = DATASET_BYTES / 3 / 64            # three arrays, unit = one CL


def run() -> str:
    out = []
    results = {}
    for label, coupled in (("haswell", False), ("snb/ivb-style", True)):
        fecm = FrequencyScaledECM(haswell_ecm("striad"), f_nominal_ghz=2.3,
                                  bw_freq_coupled=coupled)
        grids = energy_grid(fecm, PowerModel(), n_cores_max=14,
                            f_ghz_list=FREQS, total_work_units=WORK_UNITS)
        f_e, n_e, e = best_config(grids["energy_J"], FREQS)
        f_d, n_d, d = best_config(grids["edp_Js"], FREQS)
        results[label] = (e, d)
        out.append(f"== {label} ==")
        out.append("energy-to-solution [J] (rows = GHz, cols = cores 1..14):")
        out.append(table(
            ["GHz\\n"] + [str(n) for n in range(1, 15)],
            [[f] + [fmt(v, 0) for v in row]
             for f, row in zip(FREQS, grids["energy_J"])]))
        out.append(f"best energy: {e:.0f} J at {f_e} GHz x {n_e} cores")
        out.append(f"best EDP:    {d:.1f} Js at {f_d} GHz x {n_d} cores\n")
    h_e, h_d = results["haswell"]
    s_e, s_d = results["snb/ivb-style"]
    out.append(f"haswell vs snb/ivb-style: energy {s_e/h_e:.2f}x better, "
               f"EDP {s_d/h_d:.2f}x better "
               "(paper: 12-23% energy, 35-55% EDP)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
