"""Calibration-loop health: fit residuals, machine-file round-trips, and
the cold-vs-warm disk-cache speedup.

    PYTHONPATH=src python -m benchmarks.run --suite calibrate
    PYTHONPATH=src python -m benchmarks.run --json --suite calibrate

Three measurements over one machine (default ``haswell-ep``):

* **fit** — a cold :func:`repro.core.calibrate.calibrate` run against the
  simcache backend: per-field-class worst least-squares residuals (the
  ``CALIBRATE_SPEC`` gate pins the overall max), snap counts, and the
  measurement hash.  On a zoo machine every field must snap back to the
  registered prior — recalibration confirms the constants.
* **roundtrip** — the emitted versioned machine file reloads to a model
  equal to both the fitted machine and the registered prior (the
  bit-identity acceptance for golden predictions), and the checked-in
  ``src/repro/machines/*.json`` zoo files still match the registry.
* **cache** — the same calibration re-run against a warm
  :mod:`repro.core.diskcache` directory: zero new fits and zero new
  backend measurements (both asserted via the observability counters),
  with the wall-clock speedup recorded for the report.

Wall times and the speedup are volatile (excluded from ``--compare`` by
the usual naming rules); residuals, snap counts, hashes, and the boolean
identity checks are deterministic and regression-gated.
"""
from __future__ import annotations

import json
import tempfile
import time
from pathlib import Path

from .util import table

DEFAULT_MACHINE = "haswell-ep"


def fit_payload(report) -> dict:
    groups = {g: {"n": int(s["n"]), "n_snapped": int(s["n_snapped"]),
                  "max_residual": float(s["max_residual"])}
              for g, s in sorted(report.group_summary().items())}
    return {
        "base": report.base,
        "backend": report.backend,
        "snap_rtol": report.snap_rtol,
        "n_fields": len(report.fits),
        "n_snapped": sum(f.snapped for f in report.fits),
        "residual_max": float(report.residual_max()),
        "model_gap_max": max((f.model_gap for f in report.fits),
                             default=0.0),
        "groups": groups,
        "measurement_hash": report.measurement_hash,
        "fit_wall_s": float(report.wall_s),
    }


def roundtrip_payload(report) -> dict:
    """Emit the machine file, reload it, and pin the bit-identity chain."""
    from repro.core import get_machine, load_machine_file, machine_to_dict
    from repro.core.machine import MACHINES, zoo_machine_file

    prior = get_machine(report.base)
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "machine.json"
        report.save(path)
        doc = json.loads(path.read_text())
        loaded = load_machine_file(path)
    zoo_paths = sorted(zoo_machine_file("x").parent.glob("*.json"))
    zoo_ok = all(load_machine_file(f) == MACHINES[f.stem]
                 for f in zoo_paths)
    return {
        "schema": int(doc["schema"]),
        "reload_equal": loaded == report.machine,
        "machine_equal_prior": loaded == prior,
        "dict_equal_prior": (machine_to_dict(loaded)
                             == machine_to_dict(prior)),
        "zoo_files": len(zoo_paths),
        "zoo_files_match_registry": zoo_ok,
    }


def cache_payload(machine: str) -> dict:
    """Cold vs warm calibration against a fresh disk-cache directory."""
    from repro.core import calibrate as cal
    from repro.core import diskcache

    with tempfile.TemporaryDirectory() as td:
        prev = diskcache.set_cache_dir(td)
        try:
            cal.reset_counters()
            t0 = time.perf_counter()
            cold = cal.calibrate(machine)
            cold_s = time.perf_counter() - t0
            cold_fits = cal.CAL_COUNTERS["fits"]

            diskcache.clear_memo()          # force the on-disk read path
            cal.reset_counters()
            t0 = time.perf_counter()
            warm = cal.calibrate(machine)
            warm_s = time.perf_counter() - t0
            warm_fits = cal.CAL_COUNTERS["fits"]
            warm_meas = cal.CAL_COUNTERS["measurements"]
        finally:
            diskcache.restore_cache_dir(prev)
    return {
        "cold_wall_s": cold_s,
        "cold_fits": int(cold_fits),
        "warm_wall_s": warm_s,
        "speedup": cold_s / warm_s if warm_s > 0 else float("inf"),
        "warm_fits": int(warm_fits),
        "warm_measurements": int(warm_meas),
        "warm_from_cache": bool(warm.from_cache),
        "warm_identical": warm.machine == cold.machine,
    }


def calibrate_payload(machine: str = DEFAULT_MACHINE) -> dict:
    from repro.core import calibrate as cal
    from repro.core import diskcache

    prev = diskcache.set_cache_dir(None)    # the fit section runs cold
    try:
        report = cal.calibrate(machine)
    finally:
        diskcache.restore_cache_dir(prev)
    return {
        "fit": fit_payload(report),
        "roundtrip": roundtrip_payload(report),
        "cache": cache_payload(machine),
    }


def run(machine: str | None = None) -> str:
    p = calibrate_payload(machine=machine or DEFAULT_MACHINE)
    fit, rt, c = p["fit"], p["roundtrip"], p["cache"]
    rows = [
        ["fit", f"{fit['n_snapped']}/{fit['n_fields']} snapped",
         f"{fit['base']} via {fit['backend']}, "
         f"max residual {fit['residual_max']:.1e}, "
         f"model gap {fit['model_gap_max']:.1e}"],
        ["round-trip",
         "bit-identical" if rt["machine_equal_prior"] else "DRIFTED",
         f"schema v{rt['schema']}, reload == fit: {rt['reload_equal']}, "
         f"zoo files ({rt['zoo_files']}) match registry: "
         f"{rt['zoo_files_match_registry']}"],
        ["disk cache", f"{c['speedup']:.1f}x warm",
         f"warm fits {c['warm_fits']} / measurements "
         f"{c['warm_measurements']} (cold: {c['cold_fits']} fits), "
         f"identical: {c['warm_identical']}"],
    ]
    out = [table(["stage", "result", "detail"], rows)]
    for g, s in fit["groups"].items():
        out.append(f"  {g:<10} n={s['n']:<3} snapped={s['n_snapped']:<3} "
                   f"max residual {s['max_residual']:.1e}")
    out.append(f"\nmeasurement hash: {fit['measurement_hash'][:16]}... "
               f"(provenance-pinned; any backend drift moves it)")
    return "\n".join(out)
