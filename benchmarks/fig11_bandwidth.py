"""Paper Fig. 11: sustained socket bandwidth across kernels and
microarchitectures (SNB / IVB / HSW / HSW-CoD).

The sustained bandwidths are *calibration inputs* of the ECM model (the
paper measures them with likwid-bench); this benchmark derives the Fig. 11
bar heights from the model's calibration tables plus the published SNB/IVB
peak ratios, and reports the effective application bandwidth including
hidden RFO traffic (the paper's 1.3x write-allocate adjustment)."""
from __future__ import annotations

from repro.core import BENCHMARKS, HASWELL_EP
from repro.core.machine import HASWELL_CHIP_BW_NONCOD

from .util import fmt, table

#: peak sustained stream-triad chip bandwidths from the paper's Fig. 4
#: (GB/s at nominal clock) relative to Haswell, applied per kernel class.
UARCH_SCALE = {"snb": 35.5 / 52.3, "ivb": 42.5 / 52.3, "hsw": 1.0}

KERNELS = ("load", "copy", "update", "striad", "schoenauer",
           "striad_nt", "schoenauer_nt")


def run() -> str:
    rows = []
    for k in KERNELS:
        spec = BENCHMARKS[k]
        hsw_cod = HASWELL_EP.measured_bw[k] * 2      # two memory domains
        hsw = HASWELL_CHIP_BW_NONCOD[k]
        useful = (spec.loads_explicit + spec.stores + spec.nt_stores) \
            / spec.mem_streams
        rows.append([
            k,
            fmt(UARCH_SCALE["snb"] * hsw / 1e9, 1),
            fmt(UARCH_SCALE["ivb"] * hsw / 1e9, 1),
            fmt(hsw / 1e9, 1),
            fmt(hsw_cod / 1e9, 1),
            fmt(100 * useful, 0) + "%",
        ])
    out = [table(["kernel", "SNB GB/s", "IVB GB/s", "HSW", "HSW CoD",
                  "useful traffic"], rows)]
    out.append("\npaper: Haswell leads on every kernel; CoD helps all but "
               "NT-store kernels; NT stores raise useful-traffic share by "
               "dropping the RFO stream")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
