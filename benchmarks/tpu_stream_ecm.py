"""TPU adaptation of the paper's §V microbenchmark study.

For each streaming kernel (Pallas implementation in
``repro.kernels.stream``):

* validate the kernel against its pure-jnp oracle (interpret mode on CPU);
* build the TPU-ECM model analytically from the stream counts: on TPU the
  unit of work is one VMEM block row of 128 lanes; transfer terms are
  HBM<->VMEM bytes at 819 GB/s, compute on the VPU;
* the paper's non-temporal-store observation transfers structurally:
  Pallas ``out_specs`` write whole blocks, so the RFO stream does not
  exist unless the op aliases its output (``update``/``striad_rmw``),
  and the ECM-predicted NT speedup shows up as the rfo-stream delta.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import BENCHMARKS, TPU_V5E
from repro.core.ecm import ECMModel
from repro.kernels.stream import ops, ref

from .util import fmt, pred_str, table

N_ROWS, N_COLS = 512, 128          # benchmark array shape (per stream)


def tpu_stream_ecm(name: str) -> ECMModel:
    """Analytic TPU-ECM for one stream kernel, cycles per 128-lane row.

    In-core: the VPU processes 8x128 lanes/cycle -> one row costs 1/8 cy
    per vector op; DMA HBM<->VMEM moves bytes at hbm_bytes_per_cycle.
    On TPU there is no deeper shared cache: the model is {comp || 0 | vmem
    | hbm} with the VMEM edge at ~10x HBM bandwidth.
    """
    spec = BENCHMARKS[name]
    m = TPU_V5E
    row_bytes = 128 * 4                       # f32 lanes
    # streams: explicit loads + stores move through both edges; no RFO
    streams = spec.loads_explicit + spec.stores + spec.nt_stores
    rfo = spec.rfo                            # only for aliased (RMW) ops
    vpu_ops = max(spec.uop_fma + spec.uop_mul + spec.uop_add, 1)
    t_comp = vpu_ops / 8.0                    # rows/cycle on 8x128 VPU
    vmem_bpc = 8 * 128 * 4                    # VREG<->VMEM: one vector/cy
    hbm_bpc = m.hbm_bytes_per_cycle()
    t_vmem = streams * row_bytes / vmem_bpc
    t_hbm = (streams + rfo) * row_bytes / hbm_bpc
    return ECMModel(t_ol=t_comp, t_nol=0.0, transfers=(t_vmem, t_hbm),
                    levels=("VREG", "VMEM", "HBM"), unit="cy/row",
                    name=f"tpu-{name}")


def _validate() -> list[list]:
    key = jax.random.key(0)
    a, b, c, d = (jax.random.normal(jax.random.fold_in(key, i),
                                    (N_ROWS, N_COLS), jnp.float32)
                  for i in range(4))
    s = 1.7
    cases = {
        "load": (lambda: ops.load(a), lambda: ref.load(a)),
        "ddot": (lambda: ops.ddot(a, b), lambda: ref.ddot(a, b)),
        "store": (lambda: ops.store(s, (N_ROWS, N_COLS), jnp.float32),
                  lambda: ref.store(s, (N_ROWS, N_COLS), jnp.float32)),
        "update": (lambda: ops.update(s, a), lambda: ref.update(s, a)),
        "copy": (lambda: ops.copy(b), lambda: ref.copy(b)),
        "striad": (lambda: ops.striad(s, b, c), lambda: ref.striad(s, b, c)),
        "schoenauer": (lambda: ops.schoenauer(b, c, d),
                       lambda: ref.schoenauer(b, c, d)),
    }
    rows = []
    for name, (k_fn, r_fn) in cases.items():
        got, want = np.asarray(k_fn()), np.asarray(r_fn())
        err = float(np.max(np.abs(got - want)) /
                    max(np.max(np.abs(want)), 1e-9))
        ecm = tpu_stream_ecm(name)
        hbm_frac = ecm.transfers[-1] / max(ecm.prediction("HBM"), 1e-12)
        rows.append([name, "OK" if err < 1e-5 else f"ERR {err:.1e}",
                     ecm.notation(), pred_str(ecm.predictions()),
                     fmt(hbm_frac * 100, 0) + "%"])
    return rows


def run() -> str:
    rows = _validate()
    out = [table(["kernel", "pallas-vs-ref", "TPU-ECM input (cy/row)",
                  "prediction {VREG]VMEM]HBM}", "HBM-bound share"], rows)]
    # NT-store analogue: striad vs striad_rmw (aliased output = RFO stream)
    e_nt = tpu_stream_ecm("striad")            # whole-block write: no RFO
    spec = BENCHMARKS["striad"]
    m = TPU_V5E
    row_bytes = 128 * 4
    hbm_bpc = m.hbm_bytes_per_cycle()
    t_rmw = (spec.loads_explicit + spec.stores + 1) * row_bytes / hbm_bpc
    x = (e_nt.t_nol + e_nt.transfers[0] + t_rmw) / e_nt.prediction("HBM")
    out.append(
        f"\nNT-store analogue (paper §VII-E): Pallas whole-block out_specs "
        f"= NT store by construction; forcing read-modify-write of the "
        f"output (striad_rmw aliasing) adds an RFO stream -> ECM predicts "
        f"{x:.2f}x slower (paper's CPU measurement: 1.42x for Stream triad)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
