"""TPU adaptation of the paper's §V microbenchmark study.

For each streaming kernel (Pallas implementation in
``repro.kernels.stream``):

* validate the kernel against its pure-jnp oracle (interpret mode on CPU);
* build the TPU-ECM model analytically from the stream counts: on TPU the
  unit of work is one VMEM block row of 128 lanes; transfer terms are
  HBM<->VMEM bytes at 819 GB/s, compute on the VPU;
* the paper's non-temporal-store observation transfers structurally:
  Pallas ``out_specs`` write whole blocks, so the RFO stream does not
  exist unless the op aliases its output (``update``/``striad_rmw``),
  and the ECM-predicted NT speedup shows up as the rfo-stream delta.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import BENCHMARKS, TPU_V5E
from repro.core.ecm import ECMModel
from repro.core.tpu_ecm import measured_overlap
from repro.kernels.stream import ops, ref

from .util import fmt, pred_str, table

N_ROWS, N_COLS = 512, 128          # benchmark array shape (per stream)


def tpu_stream_ecm(name: str) -> ECMModel:
    """Analytic TPU-ECM for one stream kernel, cycles per 128-lane row.

    In-core: the VPU processes 8x128 lanes/cycle -> one row costs 1/8 cy
    per vector op; DMA HBM<->VMEM moves bytes at hbm_bytes_per_cycle.
    On TPU there is no deeper shared cache: the model is {comp || 0 | vmem
    | hbm} with the VMEM edge at ~10x HBM bandwidth.
    """
    spec = BENCHMARKS[name]
    m = TPU_V5E
    row_bytes = 128 * 4                       # f32 lanes
    # streams: explicit loads + stores move through both edges; no RFO
    streams = spec.loads_explicit + spec.stores + spec.nt_stores
    rfo = spec.rfo                            # only for aliased (RMW) ops
    vpu_ops = max(spec.uop_fma + spec.uop_mul + spec.uop_add, 1)
    t_comp = vpu_ops / 8.0                    # rows/cycle on 8x128 VPU
    vmem_bpc = 8 * 128 * 4                    # VREG<->VMEM: one vector/cy
    hbm_bpc = m.hbm_bytes_per_cycle()
    t_vmem = streams * row_bytes / vmem_bpc
    t_hbm = (streams + rfo) * row_bytes / hbm_bpc
    return ECMModel(t_ol=t_comp, t_nol=0.0, transfers=(t_vmem, t_hbm),
                    levels=("VREG", "VMEM", "HBM"), unit="cy/row",
                    name=f"tpu-{name}")


def _validate() -> list[list]:
    key = jax.random.key(0)
    a, b, c, d = (jax.random.normal(jax.random.fold_in(key, i),
                                    (N_ROWS, N_COLS), jnp.float32)
                  for i in range(4))
    s = 1.7
    cases = {
        "load": (lambda: ops.load(a), lambda: ref.load(a)),
        "ddot": (lambda: ops.ddot(a, b), lambda: ref.ddot(a, b)),
        "store": (lambda: ops.store(s, (N_ROWS, N_COLS), jnp.float32),
                  lambda: ref.store(s, (N_ROWS, N_COLS), jnp.float32)),
        "update": (lambda: ops.update(s, a), lambda: ref.update(s, a)),
        "copy": (lambda: ops.copy(b), lambda: ref.copy(b)),
        "striad": (lambda: ops.striad(s, b, c), lambda: ref.striad(s, b, c)),
        "schoenauer": (lambda: ops.schoenauer(b, c, d),
                       lambda: ref.schoenauer(b, c, d)),
    }
    rows = []
    for name, (k_fn, r_fn) in cases.items():
        got, want = np.asarray(k_fn()), np.asarray(r_fn())
        err = float(np.max(np.abs(got - want)) /
                    max(np.max(np.abs(want)), 1e-9))
        ecm = tpu_stream_ecm(name)
        hbm_frac = ecm.transfers[-1] / max(ecm.prediction("HBM"), 1e-12)
        rows.append([name, "OK" if err < 1e-5 else f"ERR {err:.1e}",
                     ecm.notation(), pred_str(ecm.predictions()),
                     fmt(hbm_frac * 100, 0) + "%"])
    return rows


def _time_call(fn, repeats: int = 3) -> float:
    """Best-of-N wall-clock of a jitted call (post-compile), seconds."""
    jax.block_until_ready(fn())                      # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def pipeline_timings(rows: int = N_ROWS, repeats: int = 3) -> dict:
    """Wall-clock the multi-buffered DMA pipeline at stages 1/2/3 for every
    stream kernel, plus the fused triad->update chain vs its two-launch
    composition.  Returns {kernel: {stages_1_s, stages_2_s, stages_3_s}}
    plus the fused/unfused pair and the calibrated overlap coefficient.

    On a real TPU the stages-1 -> stages-2 delta is the hidden HBM time
    (Eq. 1); in interpret mode the numbers exercise the identical code
    path and feed the perf-trajectory JSON.
    """
    key = jax.random.key(0)
    n = rows * N_COLS
    a, b, c, d = (jax.random.normal(jax.random.fold_in(key, i), (n,),
                                    jnp.float32) for i in range(4))
    s, t = 1.7, -0.3
    cases = {
        "load": lambda ns: ops.load(a, num_stages=ns),
        "ddot": lambda ns: ops.ddot(a, b, num_stages=ns),
        "store": lambda ns: ops.store(s, (n,), jnp.float32, num_stages=ns),
        "update": lambda ns: ops.update(s, a, num_stages=ns),
        "copy": lambda ns: ops.copy(b, num_stages=ns),
        "striad": lambda ns: ops.striad(s, b, c, num_stages=ns),
        "schoenauer": lambda ns: ops.schoenauer(b, c, d, num_stages=ns),
    }
    out: dict = {"kernels": {}}
    for name, fn in cases.items():
        out["kernels"][name] = {
            f"stages_{ns}_s": _time_call(lambda ns=ns: fn(ns), repeats)
            for ns in (1, 2, 3)
        }
    t_fused = _time_call(lambda: ops.triad_update(s, t, b, c), repeats)
    t_unfused = _time_call(
        lambda: ops.triad_update_unfused(s, t, b, c), repeats)
    out["fused_triad_update"] = {
        "fused_s": t_fused, "unfused_s": t_unfused,
        "speedup": t_unfused / max(t_fused, 1e-12),
        "predicted_stream_ratio": 5 / 3,
    }
    # calibrated overlap: how much of the analytic HBM term the stages-2
    # pipeline hides relative to the serial stages-1 run (striad)
    e = tpu_stream_ecm("striad")
    t_hbm_analytic = e.transfers[-1] * rows / TPU_V5E.clock_hz
    k = out["kernels"]["striad"]
    out["overlap"] = {
        "kernel": "striad",
        "t_serial_s": k["stages_1_s"],
        "t_pipelined_s": k["stages_2_s"],
        "exposed_hbm_fraction": measured_overlap(
            k["stages_1_s"], k["stages_2_s"], t_hbm_analytic),
    }
    return out


def run() -> str:
    rows = _validate()
    out = [table(["kernel", "pallas-vs-ref", "TPU-ECM input (cy/row)",
                  "prediction {VREG]VMEM]HBM}", "HBM-bound share"], rows)]
    timings = pipeline_timings(rows=128, repeats=1)
    trows = [[k, fmt(v["stages_1_s"] * 1e3, 2), fmt(v["stages_2_s"] * 1e3, 2),
              fmt(v["stages_3_s"] * 1e3, 2)]
             for k, v in timings["kernels"].items()]
    out.append("\n== multi-buffered DMA pipeline (ms, interpret mode) ==")
    out.append(table(["kernel", "stages=1 (serial)", "stages=2", "stages=3"],
                     trows))
    fu = timings["fused_triad_update"]
    out.append(
        f"fused triad->update: {fu['fused_s']*1e3:.2f} ms vs unfused "
        f"{fu['unfused_s']*1e3:.2f} ms (ECM stream count predicts "
        f"{fu['predicted_stream_ratio']:.2f}x for the memory-bound limit)")
    ov = timings["overlap"]
    out.append(
        f"calibrated overlap ({ov['kernel']}): exposed HBM fraction "
        f"{ov['exposed_hbm_fraction']:.2f} "
        "(1.0 = fully serialized, 0.0 = fully hidden; meaningful on TPU)")
    # NT-store analogue: striad vs striad_rmw (aliased output = RFO stream)
    e_nt = tpu_stream_ecm("striad")            # whole-block write: no RFO
    spec = BENCHMARKS["striad"]
    m = TPU_V5E
    row_bytes = 128 * 4
    hbm_bpc = m.hbm_bytes_per_cycle()
    t_rmw = (spec.loads_explicit + spec.stores + 1) * row_bytes / hbm_bpc
    x = (e_nt.t_nol + e_nt.transfers[0] + t_rmw) / e_nt.prediction("HBM")
    out.append(
        f"\nNT-store analogue (paper §VII-E): Pallas whole-block out_specs "
        f"= NT store by construction; forcing read-modify-write of the "
        f"output (striad_rmw aliasing) adds an RFO stream -> ECM predicts "
        f"{x:.2f}x slower (paper's CPU measurement: 1.42x for Stream triad)")
    return "\n".join(out)


def main():
    print(run())


if __name__ == "__main__":
    main()
